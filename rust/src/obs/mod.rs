//! Observability: structured, sim-time-stamped event telemetry for the
//! DES, serving, and cluster engines.
//!
//! The engines expose end-of-run aggregates
//! ([`crate::metrics::EngineCounters`] /
//! [`crate::metrics::ClusterCounters`]); this module records the event
//! *sequence* that produced them. Every scheduling decision — admission,
//! placement, shedding, step scoring, pruning, preemption, resume,
//! memory events, migration hops, fleet lifecycle transitions,
//! completion — emits a [`SimEvent`] stamped with the simulation clock,
//! GPU, request/trace id, and cause, into a [`Recorder`] attached to
//! the engine (or the cluster front door).
//!
//! **Determinism contract.** Recorders observe; they never influence
//! scheduling. An engine with no recorder attached pays one branch per
//! emission site and constructs nothing (the zero-cost disabled path,
//! measured by `benches/micro_hotpath.rs`), and a run with recorders
//! attached produces byte-identical metrics to the untraced run —
//! enforced by `tests/trace_replay.rs` and the `trace_identical` bench
//! gate.
//!
//! **Merging.** Each engine records into its own lane, so parallel
//! engine stepping (`--step-threads`) needs no synchronization; per-lane
//! streams are deterministic, and [`merge_streams`] imposes the one
//! canonical global order `(time, lane, emission index)` — identical
//! for every thread count.
//!
//! Sinks on top: a JSONL event log ([`to_jsonl`] / [`parse_jsonl`],
//! `--trace-out`) with event-kind filtering, a Chrome/Perfetto trace
//! exporter ([`perfetto::chrome_trace`], `--perfetto-out`), a
//! counters-from-events replay checker ([`replay`], `step trace-check`),
//! and a bounded flight-recorder ring ([`EventBuf::ring`]) that keeps
//! the last N events for post-mortem dumps ([`dump_tail`]).

pub mod perfetto;
pub mod replay;

use std::collections::VecDeque;

use crate::util::json::Json;

/// The event taxonomy: what happened at one scheduling decision point.
///
/// Cluster front-door kinds (`Offer`..`Depart`) are emitted by
/// `sim/cluster.rs`; engine kinds (`Admit`..`MemoryEvent`) by
/// `sim/serve.rs` and (for the single-question engine) `sim/des.rs`;
/// `Complete` is emitted by the cluster harvest at the completion
/// instant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// An arrival was presented to admission control.
    Offer,
    /// Admission routed a request onto the [`SimEvent::gpu`] engine.
    Place,
    /// A request entered the bounded admission queue.
    Queue {
        /// Queue depth immediately after the push.
        depth: usize,
    },
    /// Admission rejected a request (cause: `queue-full`, `slo`, or
    /// `stuck-queue`).
    Shed,
    /// A revocation force-clear abandoned a placed request.
    Abandon,
    /// The scaling controller activated the standby [`SimEvent::gpu`].
    ScaleUp,
    /// A GPU became active (standby activation or rejoin).
    FleetJoin,
    /// The schedule asked a GPU to leave gracefully.
    FleetLeave,
    /// A spot revocation fired against [`SimEvent::gpu`].
    Revoke {
        /// Seconds between the notice and the force-clear.
        deadline_s: f64,
    },
    /// Admission to [`SimEvent::gpu`] stopped and its drain began
    /// (cause: `leave` or `revoke`).
    Drain {
        /// Residents on the GPU when the drain started.
        residents: usize,
    },
    /// An emptied draining GPU left the fleet.
    Depart,
    /// One migration hop: a request relocated to GPU `dst` (cause:
    /// `shed-rescue`, `rebalance`, `drain`, or `rescue`).
    Migrate {
        /// Destination GPU.
        dst: usize,
        /// Prefix tokens the target recomputes to resume the traces.
        recompute_tokens: u64,
    },
    /// An engine accepted a request and admitted/queued its traces.
    Admit {
        /// Traces the request fans out into (N; 1 for CoT).
        traces: usize,
    },
    /// The step scorer evaluated one reasoning-step boundary.
    StepScore {
        /// The step score pushed into the trace's running aggregate.
        score: f64,
    },
    /// A trace was removed by a pruning policy (cause: `memory`,
    /// `slim-sc`, or `stall-drop`).
    Prune,
    /// A trace was preempted to the waiting queue by a memory event.
    Preempt,
    /// A waiting trace resumed (recompute-on-resume prefill).
    Resume,
    /// A KV-saturation memory event fired on the engine.
    MemoryEvent {
        /// Free pool blocks at the instant the event fired.
        free_blocks: usize,
    },
    /// A copy-on-write admission pinned a question's full prompt
    /// blocks fresh in the engine's prefix registry (a registry miss).
    PrefixShare {
        /// Question whose prompt blocks were pinned.
        qid: usize,
        /// Full prompt blocks pinned once for every future sharer.
        blocks: usize,
    },
    /// A copy-on-write admission reused prompt blocks already pinned
    /// in the registry (a hit: the shared span needs no prefill).
    PrefixHit {
        /// Question whose pinned blocks were reused.
        qid: usize,
        /// Pinned blocks the admission reused.
        blocks: usize,
    },
    /// Pressure evicted a zero-reference prefix-registry entry (cause
    /// `pressure`), hard-freeing its pinned blocks. The replay checker
    /// holds each `(gpu, qid)` pin to a strict share → evict
    /// alternation: shared blocks are freed exactly once.
    PrefixEvict {
        /// Question whose cached entry was evicted.
        qid: usize,
        /// Pinned blocks returned to the free pool.
        blocks: usize,
    },
    /// A request ran to completion (cause `drain` when it beat a
    /// drain deadline on a departing GPU).
    Complete,
}

/// Every kind's canonical (JSONL / `--trace-filter`) name, in taxonomy
/// order.
pub const KIND_NAMES: &[&str] = &[
    "offer",
    "place",
    "queue",
    "shed",
    "abandon",
    "scale-up",
    "fleet-join",
    "fleet-leave",
    "revoke",
    "drain",
    "depart",
    "migrate",
    "admit",
    "step-score",
    "prune",
    "preempt",
    "resume",
    "memory",
    "prefix-share",
    "prefix-hit",
    "prefix-evict",
    "complete",
];

/// The cause vocabulary (interned so [`SimEvent`] stays `Copy`).
const CAUSES: &[&str] = &[
    "queue-full",
    "slo",
    "stuck-queue",
    "deadline",
    "leave",
    "revoke",
    "shed-rescue",
    "rebalance",
    "drain",
    "rescue",
    "memory",
    "slim-sc",
    "stall-drop",
    "pressure",
];

fn intern_cause(s: &str) -> Option<&'static str> {
    CAUSES.iter().find(|&&c| c == s).copied()
}

/// Intern a pruning-signal name against the
/// [`crate::coordinator::signal::SIGNAL_NAMES`] vocabulary (interned so
/// [`SimEvent`] stays `Copy`).
fn intern_signal(s: &str) -> Option<&'static str> {
    crate::coordinator::signal::SIGNAL_NAMES.iter().find(|&&n| n == s).copied()
}

impl EventKind {
    /// The canonical name (stable; the JSONL `kind` field and the
    /// `--trace-filter` vocabulary).
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Offer => "offer",
            EventKind::Place => "place",
            EventKind::Queue { .. } => "queue",
            EventKind::Shed => "shed",
            EventKind::Abandon => "abandon",
            EventKind::ScaleUp => "scale-up",
            EventKind::FleetJoin => "fleet-join",
            EventKind::FleetLeave => "fleet-leave",
            EventKind::Revoke { .. } => "revoke",
            EventKind::Drain { .. } => "drain",
            EventKind::Depart => "depart",
            EventKind::Migrate { .. } => "migrate",
            EventKind::Admit { .. } => "admit",
            EventKind::StepScore { .. } => "step-score",
            EventKind::Prune => "prune",
            EventKind::Preempt => "preempt",
            EventKind::Resume => "resume",
            EventKind::MemoryEvent { .. } => "memory",
            EventKind::PrefixShare { .. } => "prefix-share",
            EventKind::PrefixHit { .. } => "prefix-hit",
            EventKind::PrefixEvict { .. } => "prefix-evict",
            EventKind::Complete => "complete",
        }
    }
}

/// One structured simulation event: [`kind`](Self::kind) plus the
/// context stamps shared by every kind. Engine-side emissions leave
/// [`gpu`](Self::gpu) as `None`; the cluster stamps the engine's GPU id
/// when it drains the lane.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimEvent {
    /// Simulation clock of the decision (seconds).
    pub t_s: f64,
    /// GPU the event happened on (`None`: front-door / cluster scope,
    /// or a single-engine run outside the cluster).
    pub gpu: Option<usize>,
    /// Cluster-global request id (the question id for the DES engine).
    pub rid: Option<usize>,
    /// Engine-local trace id, for trace-scoped kinds.
    pub trace: Option<usize>,
    /// Live KV-resident sequences on the engine after the event — the
    /// Perfetto live-traces counter track samples this.
    pub live: Option<usize>,
    /// KV blocks in use on the engine after the event — the Perfetto
    /// KV-occupancy counter track samples this.
    pub kv: Option<usize>,
    /// Why the decision fired (kind-specific vocabulary; see
    /// [`EventKind`]).
    pub cause: Option<&'static str>,
    /// The pruning signal behind the decision, for `step-score` and
    /// `prune` events (a [`crate::coordinator::signal::SIGNAL_NAMES`]
    /// entry) — lets `trace-check` replay attribute prunes per signal.
    pub signal: Option<&'static str>,
    /// What happened.
    pub kind: EventKind,
}

impl SimEvent {
    /// A bare event: `kind` at clock `t_s`, every stamp unset.
    pub fn new(t_s: f64, kind: EventKind) -> SimEvent {
        SimEvent {
            t_s,
            gpu: None,
            rid: None,
            trace: None,
            live: None,
            kv: None,
            cause: None,
            signal: None,
            kind,
        }
    }

    /// Stamp the GPU id.
    pub fn gpu(mut self, g: usize) -> SimEvent {
        self.gpu = Some(g);
        self
    }

    /// Stamp the request id.
    pub fn rid(mut self, rid: usize) -> SimEvent {
        self.rid = Some(rid);
        self
    }

    /// Stamp the engine-local trace id.
    pub fn trace(mut self, tid: usize) -> SimEvent {
        self.trace = Some(tid);
        self
    }

    /// Stamp the engine load sample (live sequences, KV blocks in use).
    pub fn load(mut self, live: usize, kv: usize) -> SimEvent {
        self.live = Some(live);
        self.kv = Some(kv);
        self
    }

    /// Stamp the cause.
    pub fn cause(mut self, cause: &'static str) -> SimEvent {
        self.cause = Some(cause);
        self
    }

    /// Stamp the pruning signal (a
    /// [`crate::coordinator::signal::TraceSignal::name`]).
    pub fn signal(mut self, signal: &'static str) -> SimEvent {
        self.signal = Some(signal);
        self
    }

    /// The flat JSON object form — `t`, `kind`, the set context stamps,
    /// and the kind's payload keys. Round-trips through
    /// [`from_json`](Self::from_json).
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("t", Json::Num(self.t_s)),
            ("kind", Json::Str(self.kind.name().to_string())),
        ];
        if let Some(g) = self.gpu {
            pairs.push(("gpu", Json::Num(g as f64)));
        }
        if let Some(r) = self.rid {
            pairs.push(("rid", Json::Num(r as f64)));
        }
        if let Some(t) = self.trace {
            pairs.push(("trace", Json::Num(t as f64)));
        }
        if let Some(l) = self.live {
            pairs.push(("live", Json::Num(l as f64)));
        }
        if let Some(k) = self.kv {
            pairs.push(("kv", Json::Num(k as f64)));
        }
        if let Some(c) = self.cause {
            pairs.push(("cause", Json::Str(c.to_string())));
        }
        if let Some(s) = self.signal {
            pairs.push(("signal", Json::Str(s.to_string())));
        }
        match self.kind {
            EventKind::Queue { depth } => {
                pairs.push(("depth", Json::Num(depth as f64)));
            }
            EventKind::Revoke { deadline_s } => {
                pairs.push(("deadline_s", Json::Num(deadline_s)));
            }
            EventKind::Drain { residents } => {
                pairs.push(("residents", Json::Num(residents as f64)));
            }
            EventKind::Migrate { dst, recompute_tokens } => {
                pairs.push(("dst", Json::Num(dst as f64)));
                pairs.push(("recompute_tokens", Json::Num(recompute_tokens as f64)));
            }
            EventKind::Admit { traces } => {
                pairs.push(("traces", Json::Num(traces as f64)));
            }
            EventKind::StepScore { score } => {
                pairs.push(("score", Json::Num(score)));
            }
            EventKind::MemoryEvent { free_blocks } => {
                pairs.push(("free_blocks", Json::Num(free_blocks as f64)));
            }
            EventKind::PrefixShare { qid, blocks }
            | EventKind::PrefixHit { qid, blocks }
            | EventKind::PrefixEvict { qid, blocks } => {
                pairs.push(("qid", Json::Num(qid as f64)));
                pairs.push(("blocks", Json::Num(blocks as f64)));
            }
            _ => {}
        }
        Json::obj(pairs)
    }

    /// Parse the JSON object form back into an event.
    pub fn from_json(v: &Json) -> Result<SimEvent, String> {
        let t_s = v.get("t").as_f64().ok_or("event is missing 't'")?;
        let kind_name =
            v.get("kind").as_str().ok_or("event is missing 'kind'")?.to_string();
        let num = |key: &str| -> Result<usize, String> {
            v.get(key)
                .as_usize()
                .ok_or_else(|| format!("'{kind_name}' event is missing '{key}'"))
        };
        let kind = match kind_name.as_str() {
            "offer" => EventKind::Offer,
            "place" => EventKind::Place,
            "queue" => EventKind::Queue { depth: num("depth")? },
            "shed" => EventKind::Shed,
            "abandon" => EventKind::Abandon,
            "scale-up" => EventKind::ScaleUp,
            "fleet-join" => EventKind::FleetJoin,
            "fleet-leave" => EventKind::FleetLeave,
            "revoke" => EventKind::Revoke {
                deadline_s: v
                    .get("deadline_s")
                    .as_f64()
                    .ok_or("'revoke' event is missing 'deadline_s'")?,
            },
            "drain" => EventKind::Drain { residents: num("residents")? },
            "depart" => EventKind::Depart,
            "migrate" => EventKind::Migrate {
                dst: num("dst")?,
                recompute_tokens: num("recompute_tokens")? as u64,
            },
            "admit" => EventKind::Admit { traces: num("traces")? },
            "step-score" => EventKind::StepScore {
                score: v
                    .get("score")
                    .as_f64()
                    .ok_or("'step-score' event is missing 'score'")?,
            },
            "prune" => EventKind::Prune,
            "preempt" => EventKind::Preempt,
            "resume" => EventKind::Resume,
            "memory" => EventKind::MemoryEvent { free_blocks: num("free_blocks")? },
            "prefix-share" => {
                EventKind::PrefixShare { qid: num("qid")?, blocks: num("blocks")? }
            }
            "prefix-hit" => {
                EventKind::PrefixHit { qid: num("qid")?, blocks: num("blocks")? }
            }
            "prefix-evict" => {
                EventKind::PrefixEvict { qid: num("qid")?, blocks: num("blocks")? }
            }
            "complete" => EventKind::Complete,
            other => return Err(format!("unknown event kind '{other}'")),
        };
        let cause = match v.get("cause").as_str() {
            None => None,
            Some(c) => Some(
                intern_cause(c).ok_or_else(|| format!("unknown event cause '{c}'"))?,
            ),
        };
        let signal = match v.get("signal").as_str() {
            None => None,
            Some(s) => Some(
                intern_signal(s)
                    .ok_or_else(|| format!("unknown event signal '{s}'"))?,
            ),
        };
        Ok(SimEvent {
            t_s,
            gpu: v.get("gpu").as_usize(),
            rid: v.get("rid").as_usize(),
            trace: v.get("trace").as_usize(),
            live: v.get("live").as_usize(),
            kv: v.get("kv").as_usize(),
            cause,
            signal,
            kind,
        })
    }
}

/// An event sink the engines emit into.
///
/// Recorders observe and never influence scheduling: the engines call
/// [`record`](Self::record) at decision points that already happened,
/// and an engine with no recorder attached skips event construction
/// entirely (the zero-cost disabled path). Implementations must be
/// `Send` — the cluster steps its engines in parallel — and `Debug` so
/// engine scratch state stays derivable.
pub trait Recorder: std::fmt::Debug + Send {
    /// Record one event.
    fn record(&mut self, ev: SimEvent);

    /// Drain buffered events in emission order (empty for sinks that
    /// do not buffer).
    fn drain(&mut self) -> Vec<SimEvent> {
        Vec::new()
    }

    /// Events discarded by a bounded ring (0 for unbounded sinks).
    fn dropped(&self) -> u64 {
        0
    }
}

/// The no-op recorder: every event is discarded. Attaching it measures
/// the cost of the emission path itself (event construction plus one
/// dynamic call) against the branch-only disabled path.
#[derive(Debug, Default, Clone, Copy)]
pub struct NullRecorder;

impl Recorder for NullRecorder {
    fn record(&mut self, _ev: SimEvent) {}
}

/// An in-memory event buffer: unbounded log or bounded flight-recorder
/// ring that keeps the last `cap` events (older ones are dropped and
/// counted).
#[derive(Debug, Default, Clone)]
pub struct EventBuf {
    cap: usize,
    buf: VecDeque<SimEvent>,
    dropped: u64,
}

impl EventBuf {
    /// An event buffer: `cap == 0` is the unbounded log, `cap > 0` a
    /// flight-recorder ring over the last `cap` events.
    pub fn new(cap: usize) -> EventBuf {
        EventBuf { cap, buf: VecDeque::new(), dropped: 0 }
    }

    /// The unbounded event log.
    pub fn unbounded() -> EventBuf {
        EventBuf::new(0)
    }

    /// A flight-recorder ring keeping the last `cap` events.
    pub fn ring(cap: usize) -> EventBuf {
        EventBuf::new(cap.max(1))
    }

    /// Buffered events (oldest first, drops excluded).
    pub fn events(&self) -> impl Iterator<Item = &SimEvent> {
        self.buf.iter()
    }

    /// Number of buffered events.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// Whether nothing is buffered.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

impl Recorder for EventBuf {
    fn record(&mut self, ev: SimEvent) {
        if self.cap > 0 && self.buf.len() == self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(ev);
    }

    fn drain(&mut self) -> Vec<SimEvent> {
        self.buf.drain(..).collect()
    }

    fn dropped(&self) -> u64 {
        self.dropped
    }
}

/// Merge per-lane event streams into the canonical global order.
///
/// Each stream is one `(lane, events)` pair — the cluster uses lane 0
/// for the front door and lane `g + 1` for GPU `g` — with events in
/// emission order. The merged order sorts by
/// `(time, lane, emission index)`: simulation clocks are non-negative
/// finite, so their IEEE-754 bit patterns order identically to the
/// values, and the lane/index tie-break makes the result independent of
/// how engine stepping was threaded.
pub fn merge_streams(streams: Vec<(usize, Vec<SimEvent>)>) -> Vec<SimEvent> {
    let mut keyed: Vec<(u64, usize, usize, SimEvent)> = Vec::new();
    for (lane, evs) in streams {
        for (i, ev) in evs.into_iter().enumerate() {
            keyed.push((ev.t_s.to_bits(), lane, i, ev));
        }
    }
    keyed.sort_by_key(|&(t, lane, i, _)| (t, lane, i));
    keyed.into_iter().map(|(_, _, _, ev)| ev).collect()
}

/// Validate a `--trace-filter` kind list against [`KIND_NAMES`];
/// `Err` names the first unknown kind.
pub fn validate_kinds(kinds: &[String]) -> Result<(), String> {
    for k in kinds {
        if !KIND_NAMES.contains(&k.as_str()) {
            return Err(format!(
                "unknown event kind '{k}' (expected one of: {})",
                KIND_NAMES.join(", ")
            ));
        }
    }
    Ok(())
}

/// Serialize events as JSON Lines — one compact object per line — for
/// `--trace-out`. An empty `filter` keeps every kind; otherwise only
/// events whose [`EventKind::name`] is listed are written.
pub fn to_jsonl(events: &[SimEvent], filter: &[String]) -> String {
    let mut out = String::new();
    for ev in events {
        if !filter.is_empty() && !filter.iter().any(|k| k == ev.kind.name()) {
            continue;
        }
        out.push_str(&ev.to_json().to_string_compact());
        out.push('\n');
    }
    out
}

/// Parse a JSONL event log back into events. Blank lines are skipped;
/// errors name the offending line.
pub fn parse_jsonl(text: &str) -> Result<Vec<SimEvent>, String> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let v = Json::parse(line)
            .map_err(|e| format!("line {}: invalid JSON: {e:?}", i + 1))?;
        let ev =
            SimEvent::from_json(&v).map_err(|e| format!("line {}: {e}", i + 1))?;
        out.push(ev);
    }
    Ok(out)
}

/// Render the last `n` events as a post-mortem dump — the
/// flight-recorder output printed on invariant violations and chaos
/// failures.
pub fn dump_tail(label: &str, events: &[SimEvent], n: usize) -> String {
    let tail = &events[events.len().saturating_sub(n)..];
    let mut out = format!(
        "==== {label}: last {} of {} recorded events ====\n",
        tail.len(),
        events.len()
    );
    for ev in tail {
        out.push_str(&ev.to_json().to_string_compact());
        out.push('\n');
    }
    out.push_str("==== end of flight recorder ====");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SimEvent {
        SimEvent::new(1.25, EventKind::Migrate { dst: 3, recompute_tokens: 420 })
            .gpu(1)
            .rid(7)
            .cause("rebalance")
            .load(5, 12)
    }

    #[test]
    fn signal_stamp_round_trips_and_rejects_unknowns() {
        let ev = SimEvent::new(2.0, EventKind::Prune)
            .trace(3)
            .cause("memory")
            .signal("confidence");
        let back = SimEvent::from_json(&ev.to_json()).unwrap();
        assert_eq!(back, ev);
        assert_eq!(back.signal, Some("confidence"));
        let bad = Json::obj(vec![
            ("t", Json::Num(0.0)),
            ("kind", Json::Str("prune".into())),
            ("signal", Json::Str("vibes".into())),
        ]);
        assert!(SimEvent::from_json(&bad).unwrap_err().contains("vibes"));
    }

    #[test]
    fn event_json_round_trips() {
        let ev = sample();
        let back = SimEvent::from_json(&ev.to_json()).unwrap();
        assert_eq!(back, ev);
        // Every kind round-trips, stamps or not.
        let kinds = [
            EventKind::Offer,
            EventKind::Place,
            EventKind::Queue { depth: 4 },
            EventKind::Shed,
            EventKind::Abandon,
            EventKind::ScaleUp,
            EventKind::FleetJoin,
            EventKind::FleetLeave,
            EventKind::Revoke { deadline_s: 12.5 },
            EventKind::Drain { residents: 2 },
            EventKind::Depart,
            EventKind::Migrate { dst: 0, recompute_tokens: 9 },
            EventKind::Admit { traces: 8 },
            EventKind::StepScore { score: -0.75 },
            EventKind::Prune,
            EventKind::Preempt,
            EventKind::Resume,
            EventKind::MemoryEvent { free_blocks: 3 },
            EventKind::PrefixShare { qid: 5, blocks: 7 },
            EventKind::PrefixHit { qid: 5, blocks: 7 },
            EventKind::PrefixEvict { qid: 5, blocks: 7 },
            EventKind::Complete,
        ];
        assert_eq!(kinds.len(), KIND_NAMES.len());
        for (k, name) in kinds.iter().zip(KIND_NAMES) {
            assert_eq!(k.name(), *name);
            let ev = SimEvent::new(0.5, *k);
            assert_eq!(SimEvent::from_json(&ev.to_json()).unwrap(), ev);
        }
    }

    #[test]
    fn from_json_rejects_unknowns() {
        let bad = Json::obj(vec![
            ("t", Json::Num(0.0)),
            ("kind", Json::Str("warp".into())),
        ]);
        assert!(SimEvent::from_json(&bad).unwrap_err().contains("warp"));
        let bad_cause = Json::obj(vec![
            ("t", Json::Num(0.0)),
            ("kind", Json::Str("shed".into())),
            ("cause", Json::Str("cosmic-ray".into())),
        ]);
        assert!(SimEvent::from_json(&bad_cause).unwrap_err().contains("cosmic-ray"));
    }

    #[test]
    fn ring_keeps_the_last_n_and_counts_drops() {
        let mut r = EventBuf::ring(8);
        for i in 0..20 {
            r.record(SimEvent::new(i as f64, EventKind::Offer).rid(i));
        }
        assert_eq!(r.len(), 8);
        assert_eq!(Recorder::dropped(&r), 12);
        let evs = r.drain();
        assert_eq!(evs.first().unwrap().rid, Some(12));
        assert_eq!(evs.last().unwrap().rid, Some(19));
        assert!(r.is_empty());
    }

    #[test]
    fn unbounded_buffer_never_drops() {
        let mut b = EventBuf::unbounded();
        for i in 0..1000 {
            b.record(SimEvent::new(0.0, EventKind::Offer).rid(i));
        }
        assert_eq!(b.len(), 1000);
        assert_eq!(Recorder::dropped(&b), 0);
        assert_eq!(b.events().count(), 1000);
    }

    #[test]
    fn merge_orders_by_time_then_lane_then_index() {
        let a = vec![
            SimEvent::new(1.0, EventKind::Offer).rid(0),
            SimEvent::new(3.0, EventKind::Offer).rid(1),
        ];
        let b = vec![
            SimEvent::new(1.0, EventKind::Admit { traces: 2 }).rid(0),
            SimEvent::new(2.0, EventKind::Prune).rid(0),
        ];
        let merged = merge_streams(vec![(1, b), (0, a)]);
        let kinds: Vec<&str> = merged.iter().map(|e| e.kind.name()).collect();
        assert_eq!(kinds, vec!["offer", "admit", "prune", "offer"]);
        // Same streams, any submission order: same merge.
        let t: Vec<f64> = merged.iter().map(|e| e.t_s).collect();
        assert!(t.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn jsonl_round_trips_and_filters() {
        let evs = vec![
            SimEvent::new(0.0, EventKind::Offer).rid(0),
            SimEvent::new(0.5, EventKind::Place).rid(0).gpu(2),
            SimEvent::new(1.0, EventKind::Complete).rid(0).gpu(2),
        ];
        let text = to_jsonl(&evs, &[]);
        assert_eq!(parse_jsonl(&text).unwrap(), evs);
        let only = to_jsonl(&evs, &["complete".to_string()]);
        let parsed = parse_jsonl(&only).unwrap();
        assert_eq!(parsed.len(), 1);
        assert_eq!(parsed[0].kind, EventKind::Complete);
        assert!(validate_kinds(&["complete".to_string()]).is_ok());
        assert!(validate_kinds(&["compleat".to_string()])
            .unwrap_err()
            .contains("compleat"));
    }

    #[test]
    fn parse_jsonl_names_the_bad_line() {
        let text = "{\"t\":0,\"kind\":\"offer\"}\nnot json\n";
        assert!(parse_jsonl(text).unwrap_err().starts_with("line 2"));
    }

    #[test]
    fn dump_tail_truncates_to_n() {
        let evs: Vec<SimEvent> =
            (0..10).map(|i| SimEvent::new(i as f64, EventKind::Offer).rid(i)).collect();
        let dump = dump_tail("boom", &evs, 3);
        assert!(dump.contains("last 3 of 10"));
        assert!(dump.contains("\"rid\":9"));
        assert!(!dump.contains("\"rid\":6"));
    }
}
