//! Artifact registry: parses `artifacts/manifest.json` (graph specs,
//! parameter layout, scorer bundles) and loads the raw weight slab.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One input argument of a graph.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    /// Argument name as lowered.
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Element dtype name (e.g. "float32").
    pub dtype: String,
}

/// One AOT-lowered graph.
#[derive(Debug, Clone)]
pub struct GraphSpec {
    /// HLO text file name within the artifact dir.
    pub file: String,
    /// Input arguments, in call order (after the parameters).
    pub inputs: Vec<ArgSpec>,
    /// Number of outputs in the result tuple.
    pub outputs: usize,
}

/// One parameter tensor inside params.bin.
#[derive(Debug, Clone)]
pub struct ParamEntry {
    /// Parameter name.
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Offset into the slab, in f32 elements.
    pub offset: usize,
    /// Element count.
    pub len: usize,
}

/// The served model's architecture constants.
#[derive(Debug, Clone, Copy)]
pub struct ModelConfig {
    /// Vocabulary size.
    pub vocab: usize,
    /// Embedding / residual width.
    pub d_model: usize,
    /// Transformer layer count.
    pub n_layers: usize,
    /// Attention head count.
    pub n_heads: usize,
    /// MLP hidden width.
    pub d_ff: usize,
    /// Maximum sequence length the graphs were lowered for.
    pub max_len: usize,
    /// Fixed prompt length of the prefill graphs.
    pub prompt_len: usize,
}

/// Parsed manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Build fingerprint of the artifact set.
    pub fingerprint: String,
    /// Served model architecture.
    pub model: ModelConfig,
    /// Graph name -> spec.
    pub graphs: BTreeMap<String, GraphSpec>,
    /// File name of the raw parameter slab.
    pub params_bin: String,
    /// Parameter layout within the slab.
    pub params: Vec<ParamEntry>,
    /// Scorer bundle name ("sim" / "e2e") -> file name.
    pub scorers: BTreeMap<String, String>,
    /// Prefill graph batch-size variants.
    pub prefill_batches: Vec<usize>,
    /// Decode graph batch-size variants.
    pub decode_batches: Vec<usize>,
    /// Scorer graph batch-size variants.
    pub scorer_batches: Vec<usize>,
}

impl Manifest {
    /// Parse manifest.json text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let mc = j.get("model_config");
        let model = ModelConfig {
            vocab: mc.get("vocab").as_usize().context("vocab")?,
            d_model: mc.get("d_model").as_usize().context("d_model")?,
            n_layers: mc.get("n_layers").as_usize().context("n_layers")?,
            n_heads: mc.get("n_heads").as_usize().context("n_heads")?,
            d_ff: mc.get("d_ff").as_usize().context("d_ff")?,
            max_len: mc.get("max_len").as_usize().context("max_len")?,
            prompt_len: mc.get("prompt_len").as_usize().context("prompt_len")?,
        };
        let mut graphs = BTreeMap::new();
        for (name, g) in j.get("graphs").as_obj().context("graphs")? {
            let inputs = g
                .get("inputs")
                .as_arr()
                .context("inputs")?
                .iter()
                .map(|a| {
                    Ok(ArgSpec {
                        name: a.get("name").as_str().context("input name")?.to_string(),
                        shape: a.get("shape").as_usize_vec().context("input shape")?,
                        dtype: a.get("dtype").as_str().context("input dtype")?.to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            graphs.insert(
                name.clone(),
                GraphSpec {
                    file: g.get("file").as_str().context("file")?.to_string(),
                    inputs,
                    outputs: g.get("outputs").as_usize().context("outputs")?,
                },
            );
        }
        let params = j
            .get("params")
            .as_arr()
            .context("params")?
            .iter()
            .map(|p| {
                Ok(ParamEntry {
                    name: p.get("name").as_str().context("param name")?.to_string(),
                    shape: p.get("shape").as_usize_vec().context("param shape")?,
                    offset: p.get("offset").as_usize().context("param offset")?,
                    len: p.get("len").as_usize().context("param len")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let scorers = j
            .get("scorers")
            .as_obj()
            .map(|o| {
                o.iter()
                    .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                    .collect()
            })
            .unwrap_or_default();
        Ok(Manifest {
            fingerprint: j.get("fingerprint").as_str().unwrap_or("").to_string(),
            model,
            graphs,
            params_bin: j.get("params_bin").as_str().unwrap_or("params.bin").to_string(),
            params,
            scorers,
            prefill_batches: j.get("prefill_batches").as_usize_vec().unwrap_or_default(),
            decode_batches: j.get("decode_batches").as_usize_vec().unwrap_or_default(),
            scorer_batches: j.get("scorer_batches").as_usize_vec().unwrap_or_default(),
        })
    }
}

/// An artifact directory + its manifest.
#[derive(Debug, Clone)]
pub struct Artifacts {
    /// The artifact directory.
    pub dir: PathBuf,
    /// Its parsed manifest.
    pub manifest: Manifest,
}

impl Artifacts {
    /// Load the manifest from an artifact directory.
    pub fn load(dir: impl Into<PathBuf>) -> Result<Artifacts> {
        let dir = dir.into();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {:?}/manifest.json (run `make artifacts`)", dir))?;
        Ok(Artifacts { manifest: Manifest::parse(&text)?, dir })
    }

    /// Default location: $STEP_ARTIFACTS_DIR or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("STEP_ARTIFACTS_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// The raw f32 parameter slab.
    pub fn param_data(&self) -> Result<Vec<f32>> {
        let path = self.dir.join(&self.manifest.params_bin);
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        if bytes.len() % 4 != 0 {
            bail!("params.bin size {} not a multiple of 4", bytes.len());
        }
        let mut out = Vec::with_capacity(bytes.len() / 4);
        for c in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        let expect: usize = self.manifest.params.iter().map(|p| p.len).sum();
        if out.len() != expect {
            bail!("params.bin has {} f32s, manifest expects {expect}", out.len());
        }
        Ok(out)
    }

    /// Path of a scorer bundle by name ("sim" / "e2e").
    pub fn scorer_path(&self, name: &str) -> Result<PathBuf> {
        let f = self
            .manifest
            .scorers
            .get(name)
            .with_context(|| format!("scorer '{name}' not in manifest"))?;
        Ok(self.dir.join(f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "fingerprint": "abc",
      "model_config": {"vocab": 512, "d_model": 256, "n_layers": 4,
                       "n_heads": 4, "d_ff": 1024, "max_len": 256,
                       "prompt_len": 64},
      "graphs": {
        "decode_b1": {"file": "decode_b1.hlo.txt",
          "inputs": [{"name": "embed", "shape": [512, 256], "dtype": "float32"}],
          "outputs": 3}
      },
      "params_bin": "params.bin",
      "params": [{"name": "embed", "shape": [512, 256], "offset": 0, "len": 131072}],
      "scorers": {"sim": "scorer_sim.json"},
      "prefill_batches": [1, 4, 8],
      "decode_batches": [1, 2, 4, 8],
      "scorer_batches": [1, 8, 64]
    }"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(MINI).unwrap();
        assert_eq!(m.model.vocab, 512);
        assert_eq!(m.model.prompt_len, 64);
        let g = &m.graphs["decode_b1"];
        assert_eq!(g.outputs, 3);
        assert_eq!(g.inputs[0].shape, vec![512, 256]);
        assert_eq!(m.params[0].len, 131072);
        assert_eq!(m.scorers["sim"], "scorer_sim.json");
        assert_eq!(m.decode_batches, vec![1, 2, 4, 8]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    #[test]
    fn loads_built_artifacts_if_present() {
        let dir = Artifacts::default_dir();
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this checkout
        }
        let a = Artifacts::load(&dir).unwrap();
        assert!(a.manifest.graphs.contains_key("decode_b1"));
        let data = a.param_data().unwrap();
        assert_eq!(data.len(), a.manifest.params.iter().map(|p| p.len).sum::<usize>());
        assert!(a.scorer_path("sim").unwrap().exists());
    }
}
