//! Typed executors over the AOT graphs: prefill, decode-step, scorer.
//!
//! Outputs of jax-lowered graphs arrive as a single tuple value (we lower
//! with `return_tuple=True`; the 0.5.1-era PJRT client does not untuple),
//! so each call synchronizes the tuple to host literals and decomposes
//! it. The KV cache therefore round-trips through the host each step —
//! acceptable at the e2e demo scale and noted as a known cost in
//! EXPERIMENTS.md §Perf.

use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use super::{literal_f32, literal_i32, Runtime};

fn run_tuple(
    exe: &xla::PjRtLoadedExecutable,
    args: &[&xla::Literal],
    expect: usize,
) -> Result<Vec<xla::Literal>> {
    let outs = exe.execute(args).map_err(|e| anyhow!("pjrt execute: {e:?}"))?;
    let first = outs
        .first()
        .and_then(|r| r.first())
        .context("pjrt execute returned no outputs")?;
    let mut lit = first
        .to_literal_sync()
        .map_err(|e| anyhow!("to_literal: {e:?}"))?;
    let parts = lit
        .decompose_tuple()
        .map_err(|e| anyhow!("decompose tuple: {e:?}"))?;
    if parts.len() != expect {
        bail!("graph returned {} outputs, expected {expect}", parts.len());
    }
    Ok(parts)
}

fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec<f32>: {e:?}"))
}

/// Prefill executor for one batch-size variant.
pub struct PrefillExec {
    exe: Rc<xla::PjRtLoadedExecutable>,
    pub batch: usize,
    pub prompt_len: usize,
    pub vocab: usize,
    pub d_model: usize,
}

impl PrefillExec {
    pub fn load(rt: &mut Runtime, batch: usize) -> Result<PrefillExec> {
        let m = rt.artifacts.manifest.model;
        let exe = rt.executable(&format!("prefill_b{batch}"))?;
        Ok(PrefillExec {
            exe,
            batch,
            prompt_len: m.prompt_len,
            vocab: m.vocab,
            d_model: m.d_model,
        })
    }

    /// tokens: [batch * prompt_len] i32 (PAD-padded rows).
    /// Returns (last-position logits [B][V], last hidden [B][D], kv).
    pub fn run(
        &self,
        params: &[xla::Literal],
        tokens: &[i32],
        true_lens: &[usize],
    ) -> Result<(Vec<Vec<f32>>, Vec<Vec<f32>>, xla::Literal)> {
        if tokens.len() != self.batch * self.prompt_len {
            bail!("prefill tokens len {} != {}", tokens.len(), self.batch * self.prompt_len);
        }
        let tok =
            literal_i32(tokens, &[self.batch as i64, self.prompt_len as i64])?;
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&tok);
        let parts = run_tuple(&self.exe, &args, 3)?;
        let logits = to_f32(&parts[0])?; // [B, P, V]
        let hidden = to_f32(&parts[1])?; // [B, P, D]
        let kv = parts.into_iter().nth(2).unwrap();
        let mut out_logits = Vec::with_capacity(self.batch);
        let mut out_hidden = Vec::with_capacity(self.batch);
        for b in 0..self.batch {
            let last = true_lens[b].min(self.prompt_len) - 1;
            let lo = (b * self.prompt_len + last) * self.vocab;
            out_logits.push(logits[lo..lo + self.vocab].to_vec());
            let ho = (b * self.prompt_len + last) * self.d_model;
            out_hidden.push(hidden[ho..ho + self.d_model].to_vec());
        }
        Ok((out_logits, out_hidden, kv))
    }
}

/// Decode-step executor for one batch-size variant.
pub struct DecodeExec {
    exe: Rc<xla::PjRtLoadedExecutable>,
    pub batch: usize,
    pub vocab: usize,
    pub d_model: usize,
}

impl DecodeExec {
    pub fn load(rt: &mut Runtime, batch: usize) -> Result<DecodeExec> {
        let m = rt.artifacts.manifest.model;
        let exe = rt.executable(&format!("decode_b{batch}"))?;
        Ok(DecodeExec { exe, batch, vocab: m.vocab, d_model: m.d_model })
    }

    /// One decode iteration. `kv` is the cache literal from prefill or the
    /// previous step. Returns (logits [B][V], hidden [B][D], kv').
    pub fn run(
        &self,
        params: &[xla::Literal],
        kv: &xla::Literal,
        token: &[i32],
        pos: &[i32],
    ) -> Result<(Vec<Vec<f32>>, Vec<Vec<f32>>, xla::Literal)> {
        if token.len() != self.batch || pos.len() != self.batch {
            bail!("decode batch mismatch");
        }
        let tok = literal_i32(token, &[self.batch as i64])?;
        let pos = literal_i32(pos, &[self.batch as i64])?;
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(kv);
        args.push(&tok);
        args.push(&pos);
        let parts = run_tuple(&self.exe, &args, 3)?;
        let logits = to_f32(&parts[0])?;
        let hidden = to_f32(&parts[1])?;
        let kv = parts.into_iter().nth(2).unwrap();
        let out_logits =
            logits.chunks(self.vocab).map(|c| c.to_vec()).collect();
        let out_hidden =
            hidden.chunks(self.d_model).map(|c| c.to_vec()).collect();
        Ok((out_logits, out_hidden, kv))
    }
}

/// Scorer executor (the HLO path cross-validated against the native MLP).
pub struct ScorerExec {
    exe: Rc<xla::PjRtLoadedExecutable>,
    pub batch: usize,
    pub d: usize,
    w1: xla::Literal,
    b1: xla::Literal,
    w2: xla::Literal,
    b2: xla::Literal,
}

impl ScorerExec {
    /// Load the `scorer_d{d}_b{batch}` graph plus the weight bundle
    /// `scorer_<which>.json` ("sim" or "e2e").
    pub fn load(rt: &mut Runtime, which: &str, batch: usize) -> Result<ScorerExec> {
        let path = rt.artifacts.scorer_path(which)?;
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}"))?;
        let blob = crate::util::json::Json::parse(&text)
            .map_err(|e| anyhow!("scorer json: {e}"))?;
        let d = blob.get("d").as_usize().context("d")?;
        let hidden = blob.get("hidden").as_usize().context("hidden")?;
        let w1v = blob.get("w1").as_f32_vec().context("w1")?;
        let b1v = blob.get("b1").as_f32_vec().context("b1")?;
        let w2v = blob.get("w2").as_f32_vec().context("w2")?;
        let b2v = blob.get("b2").as_f32_vec().context("b2")?;
        let exe = rt.executable(&format!("scorer_d{d}_b{batch}"))?;
        Ok(ScorerExec {
            exe,
            batch,
            d,
            w1: literal_f32(&w1v, &[d as i64, hidden as i64])?,
            b1: literal_f32(&b1v, &[hidden as i64])?,
            w2: literal_f32(&w2v, &[hidden as i64, 1])?,
            b2: literal_f32(&b2v, &[1])?,
        })
    }

    /// Score `batch` hidden states (flat [batch * d]).
    pub fn run(&self, h: &[f32]) -> Result<Vec<f32>> {
        if h.len() != self.batch * self.d {
            bail!("scorer input len {} != {}", h.len(), self.batch * self.d);
        }
        let hl = literal_f32(h, &[self.batch as i64, self.d as i64])?;
        let args = [&hl, &self.w1, &self.b1, &self.w2, &self.b2];
        let parts = run_tuple(&self.exe, &args, 1)?;
        to_f32(&parts[0])
    }
}
