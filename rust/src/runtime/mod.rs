//! PJRT runtime: loads the AOT artifacts `python/compile/aot.py` emits
//! (HLO text + manifest + weights) and executes them on the `xla` crate's
//! CPU PJRT client. Python never runs at serving time — this module is
//! the only bridge between the rust coordinator and the L2/L1 graphs.
//!
//! Interchange is HLO *text*: jax >= 0.5 emits HloModuleProto with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and aot.py).
//!
//! Everything that touches the `xla` crate is behind the `pjrt` cargo
//! feature: the default offline dependency set does not carry the crate,
//! and the experiment grid (DES engine + harness) never needs it. The
//! artifact registry stays available unconditionally.

pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod exec;

pub use artifacts::{Artifacts, GraphSpec, Manifest, ParamEntry};
#[cfg(feature = "pjrt")]
pub use exec::{DecodeExec, PrefillExec, ScorerExec};

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::rc::Rc;

#[cfg(feature = "pjrt")]
use anyhow::{anyhow, bail, Context, Result};

/// A PJRT client plus an executable cache keyed by graph name.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub artifacts: Artifacts,
    cache: HashMap<String, Rc<xla::PjRtLoadedExecutable>>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// CPU PJRT client over an artifact directory.
    pub fn new(artifact_dir: impl Into<std::path::PathBuf>) -> Result<Runtime> {
        let artifacts = Artifacts::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client, artifacts, cache: HashMap::new() })
    }

    /// Compile (once) and return the executable for a manifest graph.
    pub fn executable(&mut self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .artifacts
            .manifest
            .graphs
            .get(name)
            .with_context(|| format!("graph '{name}' not in manifest"))?;
        let path = self.artifacts.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .map_err(|e| anyhow!("parsing HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling graph '{name}': {e:?}"))?;
        let exe = Rc::new(exe);
        self.cache.insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }

    /// Model parameters as PJRT literals, in manifest order (the leading
    /// arguments of every prefill/decode call).
    pub fn param_literals(&self) -> Result<Vec<xla::Literal>> {
        let data = self.artifacts.param_data()?;
        let mut out = Vec::with_capacity(self.artifacts.manifest.params.len());
        for p in &self.artifacts.manifest.params {
            let slice = &data[p.offset..p.offset + p.len];
            let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(slice)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshaping param {}: {e:?}", p.name))?;
            out.push(lit);
        }
        Ok(out)
    }

    /// Upload literals to device buffers (for `execute_b` hot loops).
    pub fn to_device(&self, lits: &[xla::Literal]) -> Result<Vec<xla::PjRtBuffer>> {
        lits.iter()
            .map(|l| {
                self.client
                    .buffer_from_host_literal(None, l)
                    .map_err(|e| anyhow!("uploading literal: {e:?}"))
            })
            .collect()
    }
}

/// Helper: f32 literal of the given shape from a flat slice.
#[cfg(feature = "pjrt")]
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        bail!("literal_f32: {} elements vs dims {:?}", data.len(), dims);
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Helper: i32 literal of the given shape.
#[cfg(feature = "pjrt")]
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        bail!("literal_i32: {} elements vs dims {:?}", data.len(), dims);
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}
