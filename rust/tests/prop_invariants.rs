//! Property-based tests (in-tree driver; no proptest in the offline
//! vendor set): randomized operation sequences + invariant checks over
//! the coordinator substrates. Each property runs hundreds of random
//! cases drawn from a seeded RNG — failures print the seed for replay.

use step::coordinator::voting::{majority_vote, weighted_vote, Vote};
use step::kvcache::KvCacheManager;
use step::obs::replay;
use step::sim::cluster::{
    parse_fleet_events, ClusterConfig, ClusterSim, ClusterWorkload, GpuProfile,
    MigrationPolicy,
};
use step::sim::des::{DesEngine, SimConfig};
use step::sim::profiles::{BenchId, ModelId};
use step::sim::router::{GpuView, RouteRequest, RouterKind, RouterPolicy};
use step::sim::sched::{self, EventIndex};
use step::sim::serve::{ServeEngine, ServeSimConfig};
use step::sim::tracegen::{GenParams, TraceGen};
use step::sim::verifier;
use step::sim::workload::{ClosedLoopSpec, WorkloadSpec};
use step::util::rng::Rng;
use step::util::stats::{percentile, rank_acc};

/// Run `cases` random cases of a property.
fn forall(name: &str, cases: u64, mut prop: impl FnMut(&mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::new(0xBEEF ^ seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng)
        }));
        if let Err(e) = result {
            panic!("property '{name}' failed at seed {seed}: {e:?}");
        }
    }
}

// ------------------------------------------------------------- kvcache

#[test]
fn prop_kvcache_never_leaks_blocks() {
    forall("kvcache-no-leak", 200, |rng| {
        let blocks = 16 + rng.below(256);
        let mut m = KvCacheManager::new(blocks, 16);
        let mut live: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..200 {
            match rng.below(4) {
                0 => {
                    let tokens = 1 + rng.below(200);
                    if m.allocate_seq(next_id, tokens) {
                        live.push(next_id);
                    }
                    next_id += 1;
                }
                1 if !live.is_empty() => {
                    let seq = live[rng.below(live.len())];
                    // Failed appends must not change accounting.
                    let before = m.used_blocks();
                    if !m.append_tokens(seq, 1 + rng.below(64)) {
                        assert_eq!(m.used_blocks(), before);
                    }
                }
                2 if !live.is_empty() => {
                    let i = rng.below(live.len());
                    let seq = live.swap_remove(i);
                    m.free_seq(seq);
                }
                _ => {}
            }
            m.check_invariants();
        }
        for seq in live {
            m.free_seq(seq);
        }
        assert_eq!(m.used_blocks(), 0, "all blocks must return to the pool");
    });
}

#[test]
fn prop_kvcache_capacity_is_exact() {
    forall("kvcache-capacity", 100, |rng| {
        let blocks = 1 + rng.below(64);
        let mut m = KvCacheManager::new(blocks, 16);
        // Fill exactly to capacity with 16-token sequences.
        for i in 0..blocks {
            assert!(m.allocate_seq(i as u64, 16));
        }
        assert!(!m.allocate_seq(9999, 1), "over-capacity admit must fail");
        assert_eq!(m.free_blocks(), 0);
    });
}

// -------------------------------------------------------------- voting

#[test]
fn prop_voting_unanimous_wins() {
    forall("voting-unanimous", 200, |rng| {
        let ans = rng.below(100) as u32;
        let votes: Vec<Vote> = (0..1 + rng.below(64))
            .map(|_| Vote { answer: Some(ans), weight: rng.f64() + 0.01 })
            .collect();
        assert_eq!(weighted_vote(&votes), Some(ans));
    });
}

#[test]
fn prop_voting_scaling_weights_invariant() {
    // Multiplying all weights by a positive constant must not change the
    // winner.
    forall("voting-scale-invariant", 200, |rng| {
        let votes: Vec<Vote> = (0..2 + rng.below(32))
            .map(|_| Vote {
                answer: Some(rng.below(5) as u32),
                weight: rng.f64() + 0.01,
            })
            .collect();
        let scaled: Vec<Vote> = votes
            .iter()
            .map(|v| Vote { answer: v.answer, weight: v.weight * 7.5 })
            .collect();
        assert_eq!(weighted_vote(&votes), weighted_vote(&scaled));
    });
}

#[test]
fn prop_majority_matches_hand_count() {
    forall("majority-count", 200, |rng| {
        let answers: Vec<Option<u32>> = (0..1 + rng.below(64))
            .map(|_| (rng.f64() > 0.1).then(|| rng.below(4) as u32))
            .collect();
        let winner = majority_vote(&answers);
        if let Some(w) = winner {
            let count = |a: u32| answers.iter().filter(|&&x| x == Some(a)).count();
            for other in 0..4 {
                assert!(count(w) >= count(other), "hand count disagrees");
            }
        } else {
            assert!(answers.iter().all(|a| a.is_none()));
        }
    });
}

// ------------------------------------------------------------ verifier

#[test]
fn prop_verifier_reflexive_on_integers() {
    forall("verifier-reflexive", 300, |rng| {
        let v = rng.below(1_000_000) as i64 - 500_000;
        let s = format!("{v}");
        assert!(verifier::verify(&s, &s));
        assert!(verifier::verify(&format!("\\boxed{{{v}}}"), &s));
        assert!(verifier::verify(&format!("{}/{}", v * 2, 2), &s));
        assert!(!verifier::verify(&format!("{}", v + 1), &s));
    });
}

#[test]
fn prop_verifier_fraction_reduction() {
    forall("verifier-fractions", 300, |rng| {
        let p = rng.below(500) as i64 + 1;
        let q = rng.below(500) as i64 + 1;
        let k = rng.below(9) as i64 + 1;
        assert!(verifier::verify(
            &format!("{}/{}", p * k, q * k),
            &format!("{p}/{q}")
        ));
    });
}

// ---------------------------------------------------------------- stats

#[test]
fn prop_rank_acc_bounds_and_symmetry() {
    forall("rankacc-bounds", 200, |rng| {
        let pos: Vec<f64> = (0..1 + rng.below(30)).map(|_| rng.normal()).collect();
        let neg: Vec<f64> = (0..1 + rng.below(30)).map(|_| rng.normal()).collect();
        let a = rank_acc(&pos, &neg).unwrap();
        assert!((0.0..=1.0).contains(&a));
        let b = rank_acc(&neg, &pos).unwrap();
        assert!((a + b - 1.0).abs() < 1e-9, "rank_acc must be antisymmetric");
    });
}

#[test]
fn prop_percentile_monotone() {
    forall("percentile-monotone", 200, |rng| {
        let xs: Vec<f64> = (0..1 + rng.below(100)).map(|_| rng.normal()).collect();
        let mut prev = f64::NEG_INFINITY;
        for q in [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 100.0] {
            let v = percentile(&xs, q);
            assert!(v >= prev);
            prev = v;
        }
    });
}

// ------------------------------------------------- event-index differential

/// Naive mirror of one running trace for the differential test.
#[derive(Clone, Copy)]
struct NaiveTrace {
    owner: u32,
    resident: u64,
    dist: u64,
}

/// Per-trace block demand of advancing `d` tokens — the formula the
/// scan-based engines folded per probe.
fn naive_demand(c: u64, d: u64, bs: u64) -> u64 {
    (c + d).div_ceil(bs) - c.div_ceil(bs)
}

/// Differential property: under randomized insert / advance / re-key /
/// remove traffic, every [`EventIndex`] aggregate — the running set,
/// resident-token sum, `d_event`, closed-form pool and per-owner block
/// demands, and the pool- and quota-bound memory horizons — exactly
/// equals a naive per-trace scan kept alongside.
#[test]
fn prop_event_index_matches_naive_scan() {
    forall("event-index-differential", 40, |rng| {
        let bs = [8u64, 16, 32][rng.below(3)];
        let mut idx = EventIndex::new(bs as usize, true);
        let mut model: Vec<Option<NaiveTrace>> = Vec::new();

        let check = |idx: &mut EventIndex, model: &[Option<NaiveTrace>], rng: &mut Rng| {
            let live: Vec<(usize, NaiveTrace)> = model
                .iter()
                .enumerate()
                .filter_map(|(tid, t)| t.as_ref().map(|&tr| (tid, tr)))
                .collect();
            let tids: Vec<u32> = live.iter().map(|&(tid, _)| tid as u32).collect();
            assert_eq!(idx.tids(), &tids[..], "running set drift");
            assert_eq!(idx.running(), live.len());
            let resident: u64 = live.iter().map(|&(_, t)| t.resident).sum();
            assert_eq!(idx.resident_tokens(), resident, "resident-sum drift");
            let d_event = live.iter().map(|&(_, t)| t.dist).min();
            assert_eq!(idx.d_event(), d_event, "d_event drift");

            let mut owners: Vec<u32> = live.iter().map(|&(_, t)| t.owner).collect();
            owners.sort_unstable();
            owners.dedup();
            assert_eq!(idx.active_owners(), &owners[..], "active-owner drift");

            for _ in 0..4 {
                let d = 1 + rng.below(3 * bs as usize) as u64;
                let naive: u64 =
                    live.iter().map(|&(_, t)| naive_demand(t.resident, d, bs)).sum();
                assert_eq!(idx.pool_demand(d), naive, "pool demand drift at d={d}");
                for &o in &owners {
                    let naive_o: u64 = live
                        .iter()
                        .filter(|&&(_, t)| t.owner == o)
                        .map(|&(_, t)| naive_demand(t.resident, d, bs))
                        .sum();
                    assert_eq!(idx.owner_demand(o, d), naive_o, "owner {o} demand drift");
                }
            }

            // Pool-bound memory horizon: indexed closed form vs scan.
            if let Some(cap) = d_event {
                let free = rng.below(200) as u64;
                let indexed = sched::max_fitting(cap, |d| idx.pool_demand(d) <= free);
                let scanned = sched::max_fitting(cap, |d| {
                    live.iter().map(|&(_, t)| naive_demand(t.resident, d, bs)).sum::<u64>()
                        <= free
                });
                assert_eq!(indexed, scanned, "pool-bound horizon drift");

                // Quota-bound horizon: uniform per-owner headroom.
                let headroom = rng.below(40) as u64;
                let indexed = sched::max_fitting(cap, |d| {
                    idx.pool_demand(d) <= free
                        && idx.active_owners().iter().all(|&o| idx.owner_demand(o, d) <= headroom)
                });
                let scanned = sched::max_fitting(cap, |d| {
                    live.iter().map(|&(_, t)| naive_demand(t.resident, d, bs)).sum::<u64>()
                        <= free
                        && owners.iter().all(|&o| {
                            live.iter()
                                .filter(|&&(_, t)| t.owner == o)
                                .map(|&(_, t)| naive_demand(t.resident, d, bs))
                                .sum::<u64>()
                                <= headroom
                        })
                });
                assert_eq!(indexed, scanned, "quota-bound horizon drift");
            }
        };

        for _ in 0..120 {
            let live_tids: Vec<usize> = model
                .iter()
                .enumerate()
                .filter_map(|(tid, t)| t.as_ref().map(|_| tid))
                .collect();
            let dead_tids: Vec<usize> = model
                .iter()
                .enumerate()
                .filter_map(|(tid, t)| t.is_none().then_some(tid))
                .collect();
            match rng.below(5) {
                // Insert a fresh trace (admission).
                0 => {
                    let t = NaiveTrace {
                        owner: rng.below(5) as u32,
                        resident: 1 + rng.below(400) as u64,
                        dist: 1 + rng.below(40) as u64,
                    };
                    let tid = model.len();
                    idx.insert(tid as u32, t.owner, t.resident, t.dist);
                    model.push(Some(t));
                }
                // Reinsert a previously removed tid (preempt → resume:
                // same slot, grown residency, fresh boundary — the path
                // the engines take on every recompute-on-resume).
                3 if !dead_tids.is_empty() => {
                    let tid = dead_tids[rng.below(dead_tids.len())];
                    let t = NaiveTrace {
                        owner: rng.below(5) as u32,
                        resident: 1 + rng.below(600) as u64,
                        dist: 1 + rng.below(40) as u64,
                    };
                    idx.insert(tid as u32, t.owner, t.resident, t.dist);
                    model[tid] = Some(t);
                }
                // Advance to at most the event horizon, then process
                // crossings: finish (remove) or re-key, like the engines.
                1 if !live_tids.is_empty() => {
                    let d_event =
                        model.iter().flatten().map(|t| t.dist).min().expect("live traces");
                    let d = 1 + rng.below(d_event as usize) as u64;
                    idx.advance(d);
                    for tid in 0..model.len() {
                        let Some(t) = &mut model[tid] else { continue };
                        t.resident += d;
                        t.dist -= d;
                        if t.dist == 0 {
                            if rng.bernoulli(0.4) {
                                idx.remove(tid as u32);
                                model[tid] = None;
                            } else {
                                let dist = 1 + rng.below(40) as u64;
                                idx.set_boundary(tid as u32, dist);
                                model[tid].as_mut().expect("just matched").dist = dist;
                            }
                        }
                    }
                }
                // Preempt / prune a random running trace.
                2 if !live_tids.is_empty() => {
                    let tid = live_tids[rng.below(live_tids.len())];
                    idx.remove(tid as u32);
                    model[tid] = None;
                }
                _ => {}
            }
            check(&mut idx, model.as_slice(), &mut *rng);
        }
    });
}

/// Differential property: the serving engine's incrementally maintained
/// router view (`survivor_demand_blocks`) is bit-identical to the
/// sort-per-call scan reference at every event of randomized pressured
/// workloads, across methods, quotas, and seeds.
#[test]
fn prop_survivor_demand_incremental_matches_scan() {
    let gp = GenParams::default_d64();
    let scorer = proj_scorer(&gp);
    use step::coordinator::method::Method;
    let methods = [Method::Cot, Method::Sc, Method::SlimSc, Method::Step];
    forall("survivor-demand-differential", 8, |rng| {
        let mut cfg = ServeSimConfig::new(
            ModelId::Phi4_14B,
            BenchId::Hmmt2425,
            methods[rng.below(4)],
            2 + rng.below(5),
            WorkloadSpec::poisson(0.05 + rng.f64() * 0.1, 3),
        );
        cfg.mem_util = 0.45 + 0.1 * rng.below(3) as f64;
        cfg.seed = rng.next_u64();
        cfg.route_views = true;
        if rng.bernoulli(0.5) {
            cfg.quota_frac = Some(0.3 + rng.f64() * 0.4);
        }
        let gen = TraceGen::new(cfg.model, cfg.bench, gp.clone(), cfg.seed ^ 0x5EED);
        let arrivals = cfg
            .workload
            .generate(gen.bench.n_questions, cfg.seed ^ 0xA331_4A11_D00D_FEED);
        let mut eng = ServeEngine::new(&cfg, &gen, &scorer);
        for a in &arrivals {
            if eng.is_idle() {
                eng.advance_idle_to(a.t_arrive);
            }
            eng.run_until(a.t_arrive);
            eng.submit(a);
            assert_eq!(eng.survivor_demand_blocks(), eng.survivor_demand_blocks_scan());
        }
        let mut events = 0usize;
        while eng.run_one_event() {
            events += 1;
            assert_eq!(
                eng.survivor_demand_blocks(),
                eng.survivor_demand_blocks_scan(),
                "diverged at event {events}"
            );
        }
        assert_eq!(eng.survivor_demand_blocks(), 0.0, "drained engine has no demand");
    });
}

// ------------------------------------------------- sharded-router differential

/// Differential property: whenever one shard covers the whole fleet
/// (shard size >= R, i.e. shard count 1), the two-stage sharded router
/// must reproduce the flat kv-pressure placement exactly — same index,
/// same tie-breaks — over random views and requests. This is the
/// identity the cluster's incremental placement `debug_assert`s per
/// arrival; here it is exercised directly over adversarial view slices
/// (saturated pools, zero-free GPUs, heterogeneous block sizes and
/// speeds, duplicate pressure keys).
#[test]
fn prop_sharded_router_matches_flat_when_one_shard_covers_the_fleet() {
    forall("sharded-flat-differential", 400, |rng| {
        let n = 1 + rng.below(24);
        let views: Vec<GpuView> = (0..n)
            .map(|g| GpuView {
                gpu: g,
                outstanding: rng.below(8),
                live_traces: rng.below(32),
                // Small range on purpose: collisions (including hard
                // zero-free saturation) are the interesting tie cases.
                free_blocks: rng.below(6),
                pool_blocks: 64,
                block_size: [8, 16, 32][rng.below(3)],
                timing_scale: [1.0, 1.0, 2.5][rng.below(3)],
                survivor_demand_blocks: (rng.below(5) as f64) * 7.5,
            })
            .collect();
        let req = RouteRequest {
            rid: rng.below(1000),
            qid: rng.below(30),
            n_traces: 1 + rng.below(8),
            expected_tokens: (rng.below(40) as f64) * 100.0,
        };
        let mut flat = RouterKind::KvPressure.build();
        let want = flat.place(&req, &views);
        for shard_size in [n, n + rng.below(16), 1024] {
            let mut sharded = RouterKind::KvPressureSharded.build_with(shard_size);
            assert_eq!(
                sharded.place(&req, &views),
                want,
                "single-shard sharded pick must equal the flat scan \
                 (n={n}, shard_size={shard_size})"
            );
        }
    });
}

// ----------------------------------------------------- engine invariants

fn proj_scorer(gp: &GenParams) -> step::coordinator::scorer::StepScorer {
    step::harness::cells::projection_scorer(gp)
}

#[test]
fn prop_cluster_router_invariants() {
    // Across random cluster shapes (GPU count, method, router, admission
    // bounds, open/closed workloads): placement conservation
    // (offered == placed + shed, completed == placed), no outcome for a
    // shed request, per-GPU outstanding quota respected, and outcomes
    // dense/unique by rid.
    let gp = GenParams::default_d64();
    let scorer = proj_scorer(&gp);
    use step::coordinator::method::Method;
    let methods = [Method::Cot, Method::Sc, Method::SlimSc, Method::Step];
    forall("cluster-router-invariants", 10, |rng| {
        let gpus = 1 + rng.below(3);
        let method = methods[rng.below(4)];
        let router = RouterKind::ALL[rng.below(RouterKind::ALL.len())];
        let n_requests = 3 + rng.below(4);
        let workload = if rng.bernoulli(0.5) {
            ClusterWorkload::Open(WorkloadSpec::poisson(0.02 + rng.f64() * 0.1, n_requests))
        } else {
            ClusterWorkload::Closed(ClosedLoopSpec::skewed(
                1 + rng.below(3),
                5.0 + rng.f64() * 40.0,
                n_requests,
                rng.f64(),
            ))
        };
        let mut cfg = ClusterConfig::new(
            gpus,
            ModelId::Qwen3_4B,
            BenchId::GpqaDiamond,
            method,
            2 + rng.below(3),
            workload,
        );
        cfg.router = router;
        cfg.seed = rng.next_u64();
        cfg.mem_util = 0.5 + 0.1 * rng.below(5) as f64;
        cfg.admission.max_outstanding_per_gpu = 1 + rng.below(3);
        cfg.admission.queue_cap = rng.below(3);
        // Parallel engine stepping must uphold every invariant too.
        cfg.step_threads = 1 + rng.below(4);
        if rng.bernoulli(0.3) {
            cfg.admission.slo_s = Some(10.0 + rng.f64() * 500.0);
        }
        let gen = TraceGen::new(cfg.model, cfg.bench, gp.clone(), rng.next_u64());
        let r = ClusterSim::new(&cfg, &gen, &scorer).run();

        // Placement conservation.
        assert_eq!(r.counters.offered, n_requests as u64, "every request is offered");
        assert_eq!(
            r.counters.offered,
            r.counters.placed + r.counters.shed,
            "offered splits into placed + shed"
        );
        assert_eq!(r.counters.completed, r.counters.placed, "placed requests complete");
        assert_eq!(r.outcomes.len() as u64, r.counters.completed);
        assert_eq!(r.shed_rids.len() as u64, r.counters.shed);
        assert_eq!(r.latency.count(), r.counters.completed);

        // No placement to a shed request; outcome rids unique.
        for w in r.outcomes.windows(2) {
            assert!(w[0].rid < w[1].rid, "outcomes sorted and unique by rid");
        }
        for rid in &r.shed_rids {
            assert!(
                r.outcomes.iter().all(|o| o.rid != *rid),
                "shed request {rid} must not complete"
            );
        }

        // Quota respected per GPU; attribution sums to completions.
        for &peak in &r.per_gpu_peak_outstanding {
            assert!(
                peak <= cfg.admission.max_outstanding_per_gpu,
                "peak outstanding {peak} over quota {}",
                cfg.admission.max_outstanding_per_gpu
            );
        }
        assert_eq!(
            r.per_gpu_requests.iter().sum::<usize>(),
            r.outcomes.len(),
            "every completion is attributed to exactly one GPU"
        );
        assert!(r.makespan_s >= 0.0 && r.makespan_s.is_finite());
    });
}

#[test]
fn prop_cluster_migration_invariants() {
    // Across random heterogeneous pools and migration policies: no
    // trace is lost or duplicated across migrations (every outcome's
    // terminal-trace accounting stays within its budget and outcomes
    // are unique by rid), migrated requests still complete exactly
    // once (completed == placed, shed requests never complete), the
    // Never policy performs no migration, and per-GPU outstanding can
    // exceed the admission quota only by emergency relocations.
    let gp = GenParams::default_d64();
    let scorer = proj_scorer(&gp);
    use step::coordinator::method::Method;
    let policies = [
        MigrationPolicy::Never,
        MigrationPolicy::OnShed,
        MigrationPolicy::OnPressure { ratio: 1.5 },
        MigrationPolicy::OnPressure { ratio: 3.0 },
    ];
    forall("cluster-migration-invariants", 10, |rng| {
        let gpus = 2 + rng.below(3);
        let policy = policies[rng.below(4)];
        let n_requests = 4 + rng.below(5);
        let n_traces = 2 + rng.below(3);
        let mut cfg = ClusterConfig::new(
            gpus,
            ModelId::Phi4_14B,
            BenchId::Hmmt2425,
            Method::Step,
            n_traces,
            ClusterWorkload::Closed(ClosedLoopSpec::skewed(
                2 + rng.below(4),
                5.0 + rng.f64() * 30.0,
                n_requests,
                rng.f64(),
            )),
        );
        cfg.seed = rng.next_u64();
        cfg.mem_util = 0.45 + 0.1 * rng.below(3) as f64;
        cfg.migration = policy;
        // Random heterogeneous fleet: mixed sizes and speeds.
        cfg.gpu_profiles = (0..gpus)
            .map(|_| GpuProfile {
                mem_util: 0.4 + 0.1 * rng.below(6) as f64,
                block_size: 16,
                timing_scale: 1.0 + rng.f64() * 2.0,
            })
            .collect();
        cfg.admission.max_outstanding_per_gpu = 1 + rng.below(2);
        cfg.admission.queue_cap = rng.below(2);
        cfg.step_threads = 1 + rng.below(4);
        let gen = TraceGen::new(cfg.model, cfg.bench, gp.clone(), rng.next_u64());
        let r = ClusterSim::new(&cfg, &gen, &scorer).run();

        assert_eq!(r.counters.offered, n_requests as u64);
        assert_eq!(r.counters.offered, r.counters.placed + r.counters.shed);
        assert_eq!(r.counters.completed, r.counters.placed, "exactly-once completion");
        assert_eq!(r.outcomes.len() as u64, r.counters.completed);
        assert!(r.counters.migrated >= r.counters.migration_saved);
        if policy == MigrationPolicy::Never {
            assert_eq!(r.counters.migrated, 0, "Never must not migrate");
            assert_eq!(r.counters.migration_recompute_tokens, 0);
        }
        if r.counters.migrated > 0 {
            assert!(
                r.counters.migration_recompute_tokens > 0,
                "moved KV is recomputed, not teleported"
            );
        }
        // Outcomes unique by rid; shed requests never complete; every
        // request's trace accounting within its N budget (nothing lost
        // or duplicated across hops).
        for w in r.outcomes.windows(2) {
            assert!(w[0].rid < w[1].rid, "outcomes sorted and unique by rid");
        }
        for rid in &r.shed_rids {
            assert!(r.outcomes.iter().all(|o| o.rid != *rid));
        }
        for o in &r.outcomes {
            assert!(o.n_finished + o.n_pruned <= n_traces, "trace conservation");
            assert!(o.latency_s > 0.0 && o.latency_s.is_finite());
        }
        // Quota: exact under Never; relocations may exceed it, but
        // never by more than the number of migrations that happened.
        let quota = cfg.admission.max_outstanding_per_gpu;
        let slack = if policy == MigrationPolicy::Never {
            0
        } else {
            r.counters.migrated as usize
        };
        for &peak in &r.per_gpu_peak_outstanding {
            assert!(
                peak <= quota + slack,
                "peak {peak} exceeds quota {quota} + migration slack {slack}"
            );
        }
        assert_eq!(r.per_gpu_requests.iter().sum::<usize>(), r.outcomes.len());

        // Determinism under migration: a rerun reproduces the run.
        let r2 = ClusterSim::new(&cfg, &gen, &scorer).run();
        assert_eq!(r.counters.report(), r2.counters.report());
        assert_eq!(r.makespan_s, r2.makespan_s);
    });
}

#[test]
fn prop_migration_never_is_byte_identical_to_uniform_default() {
    // `MigrationPolicy::Never` + an explicit uniform profile list must
    // be byte-identical to the plain (profile-free, migration-free)
    // cluster — i.e. today's output: the heterogeneity/migration
    // plumbing is provably inert when disabled.
    let gp = GenParams::default_d64();
    let scorer = proj_scorer(&gp);
    use step::coordinator::method::Method;
    forall("migration-never-byte-identical", 6, |rng| {
        let gpus = 1 + rng.below(3);
        let n_requests = 3 + rng.below(4);
        let mut plain = ClusterConfig::new(
            gpus,
            ModelId::Qwen3_4B,
            BenchId::GpqaDiamond,
            if rng.bernoulli(0.5) { Method::Step } else { Method::Sc },
            2 + rng.below(3),
            ClusterWorkload::Closed(ClosedLoopSpec::skewed(
                1 + rng.below(3),
                10.0 + rng.f64() * 30.0,
                n_requests,
                rng.f64(),
            )),
        );
        plain.seed = rng.next_u64();
        plain.mem_util = 0.5 + 0.1 * rng.below(5) as f64;
        plain.admission.max_outstanding_per_gpu = 1 + rng.below(3);
        plain.admission.queue_cap = rng.below(3);
        let mut uniform = plain.clone();
        uniform.migration = MigrationPolicy::Never;
        uniform.gpu_profiles = vec![
            GpuProfile {
                mem_util: plain.mem_util,
                block_size: plain.block_size,
                timing_scale: 1.0,
            };
            gpus
        ];
        let gen = TraceGen::new(plain.model, plain.bench, gp.clone(), rng.next_u64());
        let a = ClusterSim::new(&plain, &gen, &scorer).run();
        let b = ClusterSim::new(&uniform, &gen, &scorer).run();
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.counters.report(), b.counters.report());
        assert_eq!(a.shed_rids, b.shed_rids);
        assert_eq!(a.outcomes.len(), b.outcomes.len());
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.rid, y.rid);
            assert_eq!(x.latency_s, y.latency_s);
            assert_eq!(x.ttfv_s, y.ttfv_s);
            assert_eq!(x.gen_tokens, y.gen_tokens);
            assert_eq!(x.chosen, y.chosen);
        }
    });
}

#[test]
fn prop_prefix_cache_off_is_byte_identical_to_default() {
    // With the prefix cache off, the CoW/affinity plumbing must be
    // provably inert: a config that only sets `affinity_weight` (cache
    // still off) is byte-identical to the plain default cluster across
    // random routers, methods, quotas, and engine-stepping thread
    // counts, and records no prefix traffic.
    let gp = GenParams::default_d64();
    let scorer = proj_scorer(&gp);
    use step::coordinator::method::Method;
    forall("prefix-off-byte-identical", 6, |rng| {
        let gpus = 1 + rng.below(3);
        let n_requests = 3 + rng.below(4);
        let mut plain = ClusterConfig::new(
            gpus,
            ModelId::Phi4_14B,
            BenchId::Hmmt2425,
            if rng.bernoulli(0.5) { Method::Step } else { Method::Sc },
            2 + rng.below(3),
            ClusterWorkload::Closed(ClosedLoopSpec::skewed(
                1 + rng.below(3),
                10.0 + rng.f64() * 30.0,
                n_requests,
                rng.f64(),
            )),
        );
        plain.router = RouterKind::ALL[rng.below(RouterKind::ALL.len())];
        plain.seed = rng.next_u64();
        plain.mem_util = 0.5 + 0.1 * rng.below(4) as f64;
        plain.admission.max_outstanding_per_gpu = 1 + rng.below(3);
        plain.admission.queue_cap = rng.below(3);
        plain.step_threads = 1 + rng.below(4);
        let mut off = plain.clone();
        off.prefix_cache = false;
        off.affinity_weight = rng.f64() * 2.0;
        let gen = TraceGen::new(plain.model, plain.bench, gp.clone(), rng.next_u64());
        let a = ClusterSim::new(&plain, &gen, &scorer).run();
        let b = ClusterSim::new(&off, &gen, &scorer).run();
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.counters.report(), b.counters.report());
        assert_eq!(a.engine_counters.report(), b.engine_counters.report());
        assert_eq!(a.shed_rids, b.shed_rids);
        assert_eq!(
            b.engine_counters.prefix_hits + b.engine_counters.prefix_misses,
            0,
            "cache off must record no prefix traffic"
        );
        assert_eq!(a.outcomes.len(), b.outcomes.len());
        for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.rid, y.rid);
            assert_eq!(x.latency_s, y.latency_s);
            assert_eq!(x.ttfv_s, y.ttfv_s);
            assert_eq!(x.gen_tokens, y.gen_tokens);
            assert_eq!(x.chosen, y.chosen);
        }
    });
}

#[test]
fn prop_prefix_cache_conserves_pins_under_random_schedules() {
    // Prefix-cache clusters under randomized routers, affinity weights,
    // migration policies, quotas, and revoking fleet schedules: the
    // event stream satisfies the pin conservation law (every shared
    // block pinned and freed exactly once, hits only against live
    // pins), counters replay byte-for-byte from events alone, prefix
    // traffic is recorded whenever anything was placed, and the whole
    // run is invariant across engine-stepping thread counts.
    let gp = GenParams::default_d64();
    let scorer = proj_scorer(&gp);
    use step::coordinator::method::Method;
    let policies = [
        MigrationPolicy::Never,
        MigrationPolicy::OnShed,
        MigrationPolicy::OnPressure { ratio: 1.5 },
    ];
    forall("prefix-pin-conservation", 6, |rng| {
        let gpus = 2 + rng.below(2);
        let n_requests = 4 + rng.below(4);
        let mut cfg = ClusterConfig::new(
            gpus,
            ModelId::Phi4_14B,
            BenchId::Hmmt2425,
            Method::Step,
            3 + rng.below(3),
            ClusterWorkload::Closed(ClosedLoopSpec::skewed(
                2 + rng.below(3),
                5.0 + rng.f64() * 30.0,
                n_requests,
                rng.f64(),
            )),
        );
        cfg.prefix_cache = true;
        cfg.affinity_weight = [0.0, 0.25, 0.5][rng.below(3)];
        cfg.router = if rng.bernoulli(0.5) {
            RouterKind::KvPressure
        } else {
            RouterKind::KvPressureSharded
        };
        cfg.seed = rng.next_u64();
        cfg.mem_util = 0.45 + 0.05 * rng.below(4) as f64;
        cfg.migration = policies[rng.below(3)];
        cfg.admission.max_outstanding_per_gpu = 1 + rng.below(3);
        cfg.event_log = Some(0);
        cfg.step_threads = 1 + rng.below(4);
        if rng.bernoulli(0.5) {
            cfg.standby = 1;
            cfg.fleet_events =
                parse_fleet_events("30:0:revoke:10", gpus, 1).expect("valid fleet spec");
        }
        let gen = TraceGen::new(cfg.model, cfg.bench, gp.clone(), rng.next_u64());
        let r = ClusterSim::new(&cfg, &gen, &scorer).run();

        let report = replay::check(&r.events);
        assert!(report.ok(), "pin conservation violated: {:?}", report.violations);
        assert_eq!(
            report.counters.report(),
            r.counters.report(),
            "events do not replay the counters"
        );
        let ec = &r.engine_counters;
        if r.counters.placed > 0 {
            assert!(ec.prefix_misses > 0, "a placed request pins its prompt");
        }
        assert!(
            ec.prefix_evictions <= ec.prefix_misses,
            "each eviction retires an entry pinned by exactly one miss"
        );

        // Thread invariance: a different step-thread count reproduces
        // the run exactly, events and all.
        let mut threaded = cfg.clone();
        threaded.step_threads = cfg.step_threads % 4 + 1;
        let r2 = ClusterSim::new(&threaded, &gen, &scorer).run();
        assert_eq!(r.counters.report(), r2.counters.report());
        assert_eq!(r.engine_counters.report(), r2.engine_counters.report());
        assert_eq!(r.makespan_s, r2.makespan_s);
        assert_eq!(r.events, r2.events, "merged event stream is not canonical");
    });
}

#[test]
fn prop_engine_conservation_laws() {
    // Across random methods/budgets/memory settings: token conservation,
    // wait+decode <= latency per trace, engine timeline decomposes
    // latency, STEP never waits, CoT never prunes, and determinism.
    let gp = GenParams::default_d64();
    let scorer = proj_scorer(&gp);
    use step::coordinator::method::Method;
    forall("engine-conservation", 40, |rng| {
        let method = Method::ALL[rng.below(5)];
        let model = ModelId::ALL[rng.below(3)];
        let bench = BenchId::ALL[rng.below(5)];
        let mut cfg = SimConfig::new(model, bench, method, 8 + rng.below(4) * 8);
        cfg.mem_util = 0.5 + 0.1 * rng.below(5) as f64;
        cfg.seed = rng.next_u64();
        let gen = TraceGen::new(model, bench, gp.clone(), rng.next_u64());
        let engine = DesEngine::new(&cfg, &gen, &scorer);
        let qid = rng.below(20);
        let r = engine.run_question(qid);

        let sum: u64 = r.traces.iter().map(|t| t.generated).sum();
        assert_eq!(sum, r.gen_tokens, "token conservation");
        assert!(r.latency_s.is_finite() && r.latency_s > 0.0);
        for t in &r.traces {
            assert!(t.wait_s + t.decode_s <= r.latency_s + 1e-6);
            assert!(t.wait_s >= 0.0 && t.decode_s >= 0.0);
        }
        assert!(
            (r.engine_wait_s + r.engine_decode_s - r.latency_s).abs()
                < 1e-6 * r.latency_s.max(1.0),
            "engine timeline must decompose latency"
        );
        if method == Method::Step {
            assert_eq!(r.n_preemptions, 0, "STEP never preempts");
            assert_eq!(r.engine_wait_s, 0.0);
        }
        if method == Method::Cot {
            assert_eq!(r.traces.len(), 1);
            assert_eq!(r.n_pruned, 0);
        }

        // Determinism.
        let r2 = engine.run_question(qid);
        assert_eq!(r.gen_tokens, r2.gen_tokens);
        assert_eq!(r.chosen, r2.chosen);
        assert!((r.latency_s - r2.latency_s).abs() < 1e-9);
    });
}
