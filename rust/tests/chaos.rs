//! Chaos harness: deterministic fleet-failure injection against the
//! cluster simulator. One shared driver — the seeded `FleetEvent`
//! schedule generator (`random_fleet_events`, also reachable as
//! `--fleet-events rand:SEED:N:HORIZON_S`) — feeds every property:
//!
//! * **exactly-once conservation** — under any revocation schedule, no
//!   request is lost or duplicated: every offered request is either an
//!   outcome or a dropped rid, never both, never twice;
//! * **clean departure** — a revoked GPU holds zero residents once it
//!   departs, and an applied revocation departs by its deadline;
//! * **static-fleet identity** — an empty `--fleet-events` schedule
//!   (and an untouched standby pool) is byte-identical to today's
//!   static fleet.
//!
//! Schedules are deterministic in the seed, so every run of this suite
//! exercises the same chaos byte-for-byte.
//!
//! Every chaos run keeps a bounded flight-recorder ring
//! (`ClusterConfig::event_log`); when a property panics, a drop guard
//! dumps the last recorded events so the failing schedule's end-state
//! is in the test output, not just the assertion message.

use step::coordinator::method::Method;
use step::harness::cells::projection_scorer;
use step::harness::table6::{self, ClusterOpts};
use step::sim::cluster::{
    random_fleet_events, ClusterConfig, ClusterResult, ClusterSim, ClusterWorkload,
    FleetAction, FleetEvent, FleetLogKind, MigrationPolicy,
};
use step::sim::profiles::{BenchId, ModelId};
use step::sim::tracegen::{GenParams, TraceGen};
use step::sim::workload::WorkloadSpec;

/// 3 active + 2 standby GPUs under an open-loop workload whose service
/// times (Phi-4 on HMMT) run long enough that mid-run chaos reliably
/// catches live residents.
fn chaos_cfg(
    seed: u64,
    schedule: Vec<FleetEvent>,
    migration: MigrationPolicy,
) -> ClusterConfig {
    ClusterConfig::builder(
        3,
        ModelId::Phi4_14B,
        BenchId::Hmmt2425,
        Method::Step,
        4,
        ClusterWorkload::Open(WorkloadSpec::poisson(0.5, 10)),
    )
    .seed(seed)
    .standby(2)
    .scale_up_queue_depth(2)
    .migration(migration)
    .fleet_events(schedule)
    // Bounded flight-recorder ring per lane: cheap enough to leave on
    // for every chaos run (the determinism contract says it cannot
    // change the results), deep enough to explain a failure.
    .event_log(Some(256))
    .build()
}

fn run(cfg: &ClusterConfig) -> ClusterResult {
    let gp = GenParams::default_d64();
    let scorer = projection_scorer(&gp);
    let gen = TraceGen::new(cfg.model, cfg.bench, gp, cfg.seed ^ 0x5EED);
    ClusterSim::new(cfg, &gen, &scorer).run()
}

/// Drop guard over a run's flight-recorder ring: dumps the tail of the
/// recorded events iff the test body panics past it.
struct FlightRecorder {
    label: String,
    events: Vec<step::obs::SimEvent>,
}

impl FlightRecorder {
    fn arm(label: &str, r: &ClusterResult) -> FlightRecorder {
        FlightRecorder { label: label.to_string(), events: r.events.clone() }
    }
}

impl Drop for FlightRecorder {
    fn drop(&mut self) {
        if std::thread::panicking() {
            eprintln!("{}", step::obs::dump_tail(&self.label, &self.events, 64));
        }
    }
}

/// The shared chaos driver is a pure function of its seed, time-sorted,
/// in-bounds, and spec-round-trippable.
#[test]
fn chaos_driver_is_deterministic_and_well_formed() {
    let a = random_fleet_events(42, 4, 3, 12, 600.0);
    assert_eq!(a, random_fleet_events(42, 4, 3, 12, 600.0), "same seed, same schedule");
    assert_ne!(a, random_fleet_events(43, 4, 3, 12, 600.0), "seeds diverge");
    assert_eq!(a.len(), 12);
    for w in a.windows(2) {
        assert!(w[0].t_s <= w[1].t_s, "schedules are time-sorted");
    }
    for e in &a {
        assert!(e.gpu < 7, "targets stay inside active + standby");
        assert!(e.t_s >= 0.0 && e.t_s <= 600.0);
        if let FleetAction::Revoke { deadline_s } = e.action {
            assert!(deadline_s > 0.0 && deadline_s.is_finite());
        }
    }
    // The generated schedule round-trips through the CLI spelling.
    let spec: Vec<String> = a.iter().map(|e| e.spec()).collect();
    assert_eq!(
        step::sim::cluster::parse_fleet_events(&spec.join(";"), 4, 3),
        Some(a)
    );
}

/// Exactly-once completion conservation under randomized revocation
/// schedules, with and without the drain controller: every offered
/// request is either an outcome or a dropped rid — never both, never
/// twice, none missing — and the counter laws hold.
#[test]
fn no_request_lost_or_duplicated_under_any_revocation_schedule() {
    for seed in 0..6u64 {
        let schedule = random_fleet_events(seed, 3, 2, 5, 180.0);
        for policy in [MigrationPolicy::Never, MigrationPolicy::OnShed] {
            let r = run(&chaos_cfg(seed, schedule.clone(), policy));
            let label = format!("seed {seed} policy {}", policy.name());
            let _flight = FlightRecorder::arm(&label, &r);
            assert_eq!(r.counters.offered, 10, "{label}");
            assert_eq!(
                r.counters.offered,
                r.counters.placed + r.counters.shed,
                "{label}: admission conservation"
            );
            assert_eq!(
                r.counters.completed + r.counters.shed_on_revoke,
                r.counters.placed,
                "{label}: every placed request completes or is abandoned"
            );
            let mut seen = vec![0u32; 10];
            for o in &r.outcomes {
                seen[o.rid] += 1;
            }
            for &rid in &r.shed_rids {
                seen[rid] += 1;
            }
            for (rid, &n) in seen.iter().enumerate() {
                assert_eq!(n, 1, "{label}: rid {rid} seen {n} times");
            }
            for w in r.outcomes.windows(2) {
                assert!(w[0].rid < w[1].rid, "{label}: outcomes sorted by rid");
            }
        }
    }
}

/// Every departure in the fleet log — drain completion, deadline
/// force-clear, or graceful leave — leaves zero residents behind, pairs
/// with an earlier drain-start, and an applied revocation departs no
/// later than its deadline.
#[test]
fn revoked_gpus_hold_zero_residents_after_their_deadline() {
    for seed in [1u64, 4, 9] {
        let schedule = random_fleet_events(seed, 3, 2, 6, 200.0);
        let scheduled_revokes = schedule
            .iter()
            .filter(|e| matches!(e.action, FleetAction::Revoke { .. }))
            .count() as u64;
        let r = run(&chaos_cfg(seed, schedule.clone(), MigrationPolicy::OnShed));
        let _flight = FlightRecorder::arm(&format!("seed {seed} clean-departure"), &r);
        assert!(
            r.counters.revocations <= scheduled_revokes,
            "seed {seed}: only scheduled revocations can fire"
        );
        let mut drain_started = vec![false; 5];
        for e in &r.fleet_log {
            match e.kind {
                FleetLogKind::DrainStarted => drain_started[e.gpu] = true,
                FleetLogKind::Departed => {
                    assert!(
                        drain_started[e.gpu],
                        "seed {seed}: gpu {} departed without draining",
                        e.gpu
                    );
                    assert_eq!(
                        e.residents_after, 0,
                        "seed {seed}: gpu {} departed with residents",
                        e.gpu
                    );
                    drain_started[e.gpu] = false;
                }
                FleetLogKind::Joined => {}
            }
        }
        // A revocation that applied (drain-start logged at its instant)
        // must produce a departure by its deadline.
        for ev in &schedule {
            let FleetAction::Revoke { deadline_s } = ev.action else { continue };
            let applied = r.fleet_log.iter().any(|l| {
                l.kind == FleetLogKind::DrainStarted && l.gpu == ev.gpu && l.t_s == ev.t_s
            });
            if applied {
                assert!(
                    r.fleet_log.iter().any(|l| {
                        l.kind == FleetLogKind::Departed
                            && l.gpu == ev.gpu
                            && l.t_s >= ev.t_s
                            && l.t_s <= ev.t_s + deadline_s + 1e-9
                    }),
                    "seed {seed}: revoked gpu {} missed its deadline",
                    ev.gpu
                );
            }
        }
    }
}

/// An explicit two-revocation schedule: both fire, both victims depart
/// empty by their deadlines, and the drain controller strictly beats
/// abandoning the residents.
#[test]
fn explicit_revocations_drain_and_beat_shedding_everything() {
    let schedule = step::sim::cluster::parse_fleet_events("25:0:revoke:15;40:1:revoke:15", 3, 2)
        .expect("valid explicit spec");
    let never = run(&chaos_cfg(3, schedule.clone(), MigrationPolicy::Never));
    let drained = run(&chaos_cfg(3, schedule, MigrationPolicy::OnShed));
    let _flight_n = FlightRecorder::arm("explicit-revocations never", &never);
    let _flight_d = FlightRecorder::arm("explicit-revocations on-shed", &drained);
    for (r, label) in [(&never, "never"), (&drained, "on-shed")] {
        assert_eq!(r.counters.revocations, 2, "{label}");
        assert_eq!(
            r.outcomes.len() as u64 + r.shed_rids.len() as u64,
            r.counters.offered,
            "{label}: exactly-once"
        );
        let departures = r
            .fleet_log
            .iter()
            .filter(|e| e.kind == FleetLogKind::Departed && e.residents_after == 0)
            .count();
        assert!(departures >= 2, "{label}: both victims depart empty");
    }
    assert!(never.counters.shed_on_revoke > 0, "shed-everything abandons work");
    assert!(drained.counters.rescue_migrated > 0, "the drain controller relocates");
    assert!(
        drained.counters.goodput_lost_per_revocation()
            < never.counters.goodput_lost_per_revocation(),
        "drain-relocate must lose strictly less per revocation: {} vs {}",
        drained.counters.report(),
        never.counters.report()
    );
}

/// Revocation while prompt prefixes are shared: a prefix-cache cluster
/// under the explicit two-revocation schedule still conserves requests
/// exactly once, records shared admissions (pins and sibling hits),
/// satisfies the pin conservation law on the full event stream — every
/// shared block pinned and freed exactly once, even on GPUs that
/// drain, relocate their residents, and depart — and reruns
/// byte-identically.
#[test]
fn revocation_while_prefixes_are_shared_conserves_pins() {
    let schedule = step::sim::cluster::parse_fleet_events("25:0:revoke:15;40:1:revoke:15", 3, 2)
        .expect("valid explicit spec");
    let mut c = chaos_cfg(3, schedule, MigrationPolicy::OnShed);
    c.prefix_cache = true;
    c.affinity_weight = 0.5;
    // Unbounded log: the replay checker needs the whole ledger, not the
    // flight-recorder tail.
    c.event_log = Some(0);
    let r = run(&c);
    let _flight = FlightRecorder::arm("revoke-while-shared", &r);
    assert_eq!(r.counters.revocations, 2);
    assert_eq!(
        r.outcomes.len() as u64 + r.shed_rids.len() as u64,
        r.counters.offered,
        "exactly-once under revocation with shared prefixes"
    );
    assert!(r.engine_counters.prefix_misses > 0, "prompts were pinned");
    assert!(r.engine_counters.prefix_hits > 0, "sibling traces shared the pins");
    let report = step::obs::replay::check(&r.events);
    assert!(report.ok(), "pin conservation violated: {:?}", report.violations);
    assert_eq!(
        report.counters.report(),
        r.counters.report(),
        "events do not replay the counters"
    );
    // Departed victims left nothing pinned behind them.
    for e in &r.fleet_log {
        if e.kind == FleetLogKind::Departed {
            assert_eq!(e.residents_after, 0, "gpu {} departed with residents", e.gpu);
        }
    }
    // Determinism: the chaos run reproduces byte-for-byte.
    let r2 = run(&c);
    assert_eq!(r.counters.report(), r2.counters.report());
    assert_eq!(r.engine_counters.report(), r2.engine_counters.report());
    assert_eq!(r.events, r2.events, "event stream is not reproducible");
}

/// The flight recorder actually records: under a revoking schedule the
/// bounded ring is non-empty, stays within its per-lane budget, and
/// carries the fleet-transition kinds a post-mortem needs.
#[test]
fn flight_recorder_ring_is_bounded_and_sees_the_chaos() {
    let schedule = step::sim::cluster::parse_fleet_events("25:0:revoke:15;40:1:revoke:15", 3, 2)
        .expect("valid explicit spec");
    let r = run(&chaos_cfg(3, schedule, MigrationPolicy::OnShed));
    assert!(!r.events.is_empty(), "the ring recorded nothing");
    // 256 events per lane: the front door plus every engine slot.
    let lanes = 3 + 2 + 1;
    assert!(r.events.len() <= 256 * lanes, "{} events exceed the ring budget", r.events.len());
    let kinds: Vec<&str> = r.events.iter().map(|e| e.kind.name()).collect();
    for k in ["revoke", "drain", "complete"] {
        assert!(kinds.contains(&k), "ring is missing '{k}' events");
    }
}

/// An empty `--fleet-events` schedule produces byte-identical
/// `BENCH_cluster.json` metric blocks to the static fleet, and an
/// untouched standby pool changes nothing either — the elastic
/// plumbing is invisible until an event or the scaling controller
/// fires.
#[test]
fn empty_fleet_events_is_byte_identical_to_the_static_fleet() {
    let gp = GenParams::default_d64();
    let sc = projection_scorer(&gp);
    let base = ClusterOpts {
        gpus: 2,
        model: ModelId::Qwen3_4B,
        bench: BenchId::GpqaDiamond,
        n_requests: 4,
        clients: 2,
        think_s: 20.0,
        n_traces: 4,
        seed: 7,
        threads: 1,
        ..Default::default()
    };
    assert_eq!(base.fleet_events, "", "the default schedule is empty");
    let (m0, r0) = table6::run_grids(&base, &gp, &sc);
    // Inert standby: no event, light load, controller threshold unmet.
    let standby = ClusterOpts { standby: 2, ..base.clone() };
    let (m1, r1) = table6::run_grids(&standby, &gp, &sc);
    assert_eq!(
        table6::cells_fingerprint(&m0),
        table6::cells_fingerprint(&m1),
        "standby pool changed the methods grid bytes"
    );
    assert_eq!(
        table6::cells_fingerprint(&r0),
        table6::cells_fingerprint(&r1),
        "standby pool changed the routers grid bytes"
    );
}
