//! The parallel harness contract: sharding questions (or whole cells)
//! across worker threads produces *byte-identical* results to a serial
//! run. Every RNG stream in the simulator derives from (seed, qid), the
//! pool returns results in index order, and the aggregation fold is
//! serial — so JSON output must not differ in a single byte.
//!
//! The same contract covers the serving layer: workload arrival
//! sequences are pure functions of (spec, seed), and the serve-sim
//! metric blocks are byte-identical for any `--threads` value.

use step::coordinator::method::Method;
use step::harness::cells::{
    projection_scorer, run_cell, run_cell_with, run_cells, CellJob, CellOpts,
};
use step::harness::table5::{metrics_json, run_methods, ServingOpts};
use step::harness::table6;
use step::harness::table6::ClusterOpts;
use step::sim::profiles::{BenchId, ModelId};
use step::sim::tracegen::GenParams;
use step::sim::workload::{ClosedLoopSpec, WorkloadSpec};

fn opts(threads: usize) -> CellOpts {
    CellOpts {
        n_traces: 8,
        max_questions: Some(3),
        threads,
        ..Default::default()
    }
}

/// 2 methods x 3 questions x 8 traces under 1 vs 4 threads: the
/// CellResult JSON must be byte-identical.
#[test]
fn question_sharding_is_byte_identical() {
    let gp = GenParams::default_d64();
    let sc = projection_scorer(&gp);
    for method in [Method::Sc, Method::Step] {
        let one = run_cell(ModelId::Qwen3_4B, BenchId::Aime25, method, &gp, &sc, &opts(1))
            .to_json()
            .to_string_pretty();
        let four = run_cell(ModelId::Qwen3_4B, BenchId::Aime25, method, &gp, &sc, &opts(4))
            .to_json()
            .to_string_pretty();
        assert_eq!(one, four, "{method:?}: parallel cell differs from serial");
    }
}

/// The per-question callback fires in qid order regardless of which
/// worker computed each question.
#[test]
fn callback_order_is_qid_order_under_parallelism() {
    let gp = GenParams::default_d64();
    let sc = projection_scorer(&gp);
    let mut seen = Vec::new();
    let mut cb = |r: &step::sim::des::QuestionResult| seen.push(r.qid);
    run_cell_with(
        ModelId::Qwen3_4B,
        BenchId::Aime25,
        Method::Step,
        &gp,
        &sc,
        &opts(4),
        Some(&mut cb),
    );
    assert_eq!(seen, vec![0, 1, 2]);
}

/// Cell-level sharding (the table path) is deterministic too, including
/// a thread count that does not divide the job count.
#[test]
fn cell_sharding_is_byte_identical() {
    let gp = GenParams::default_d64();
    let sc = projection_scorer(&gp);
    let jobs: Vec<CellJob> = [Method::Cot, Method::Sc, Method::SlimSc, Method::Step]
        .into_iter()
        .map(|method| CellJob {
            model: ModelId::DeepSeek8B,
            bench: BenchId::Aime25,
            method,
            opts: opts(1),
        })
        .collect();
    let render = |cells: &[step::harness::cells::CellResult]| -> String {
        cells
            .iter()
            .map(|c| c.to_json().to_string_pretty())
            .collect::<Vec<_>>()
            .join("\n")
    };
    let serial = render(&run_cells(&jobs, &gp, &sc, 1));
    for threads in [2, 3, 4] {
        assert_eq!(
            serial,
            render(&run_cells(&jobs, &gp, &sc, threads)),
            "{threads}-thread grid differs from serial"
        );
    }
}

/// Property: workload arrival sequences are a pure function of
/// (spec, seed) — identical across calls, sensitive to the seed, and
/// (trivially) invariant to any thread count, since generation happens
/// before any sharding.
#[test]
fn workload_generation_is_deterministic_per_seed() {
    for spec in [
        WorkloadSpec::poisson(0.5, 64),
        WorkloadSpec::poisson(8.0, 64),
        WorkloadSpec::bursty(2.0, 4, 64),
    ] {
        for seed in [0u64, 7, 0xDEAD_BEEF] {
            let a = spec.generate(30, seed);
            let b = spec.generate(30, seed);
            assert_eq!(a, b, "same (spec, seed) must reproduce byte-identically");
            assert_eq!(a.len(), 64);
            assert!(a.windows(2).all(|w| w[0].t_arrive <= w[1].t_arrive));
        }
        assert_ne!(
            spec.generate(30, 1),
            spec.generate(30, 2),
            "different seeds must give different workloads"
        );
    }
}

/// Property: the closed-loop generator is a pure function of
/// (spec, seed, completion history) — replaying the same completion
/// schedule reproduces the arrival stream byte-identically, a different
/// seed diverges, and the request budget caps the stream.
#[test]
fn closed_loop_workload_is_deterministic() {
    let spec = ClosedLoopSpec::skewed(4, 25.0, 20, 0.5);
    let drive = |seed: u64| -> Vec<step::sim::workload::Arrival> {
        let mut cl = spec.clients(12, vec![3, 8, 11], seed);
        let mut out = cl.initial_arrivals();
        // A fixed synthetic completion schedule: client c's request
        // completes 40s after issue, cycling clients.
        let mut t = 40.0;
        let mut c = 0usize;
        while let Some(a) = cl.next_arrival(c, t) {
            t = a.t_arrive + 40.0;
            c = (c + 1) % 4;
            out.push(a);
        }
        out
    };
    let a = drive(9);
    assert_eq!(a, drive(9), "same (spec, seed, history) must replay exactly");
    assert_ne!(a, drive(10), "different seeds must diverge");
    assert_eq!(a.len(), 20, "the budget caps the stream");
    for (i, arr) in a.iter().enumerate() {
        assert_eq!(arr.rid, i, "request ids are dense in issue order");
    }
}

/// The cluster-sim acceptance contract: `--threads 1` and `--threads 8`
/// produce byte-identical BENCH_cluster.json metric blocks, and reruns
/// reproduce them exactly (the determinism contract extended to the
/// cluster layer).
#[test]
fn cluster_metric_blocks_are_thread_invariant() {
    let gp = GenParams::default_d64();
    let sc = projection_scorer(&gp);
    let base = ClusterOpts {
        gpus: 2,
        model: ModelId::Qwen3_4B,
        bench: BenchId::GpqaDiamond,
        n_requests: 4,
        clients: 2,
        think_s: 20.0,
        n_traces: 4,
        seed: 7,
        threads: 1,
        ..Default::default()
    };
    let (m, r) = table6::run_grids(&base, &gp, &sc);
    let serial = table6::metrics_json(&base, &m, &r).to_string_pretty();
    for threads in [2, 8] {
        let opts = ClusterOpts { threads, ..base.clone() };
        let (m, r) = table6::run_grids(&opts, &gp, &sc);
        let sharded = table6::metrics_json(&opts, &m, &r).to_string_pretty();
        assert_eq!(serial, sharded, "{threads}-thread cluster metrics differ from serial");
    }
    // Across runs at the same thread count: byte-identical too.
    let (m2, r2) = table6::run_grids(&base, &gp, &sc);
    assert_eq!(serial, table6::metrics_json(&base, &m2, &r2).to_string_pretty());
}

/// Parallel *engine stepping* inside one cluster simulation (advancing
/// the R per-GPU engines concurrently between interaction points via
/// `pool::parallel_for_each_mut`) is byte-identical to the serial
/// engine loop: the engines share no state between arrivals and
/// completions merge in GPU order either way.
#[test]
fn cluster_parallel_engine_stepping_is_byte_identical() {
    let gp = GenParams::default_d64();
    let sc = projection_scorer(&gp);
    let base = ClusterOpts {
        gpus: 4,
        model: ModelId::Phi4_14B,
        bench: BenchId::Hmmt2425,
        n_requests: 8,
        clients: 4,
        think_s: 20.0,
        heavy_frac: 0.5,
        n_traces: 4,
        mem_util: 0.5,
        seed: 7,
        threads: 1,
        step_threads: 1,
        ..Default::default()
    };
    let (m, r) = table6::run_grids(&base, &gp, &sc);
    let serial = table6::metrics_json(&base, &m, &r).to_string_pretty();
    for step_threads in [2, 4, 8, 0] {
        let opts = ClusterOpts { step_threads, ..base.clone() };
        let (m, r) = table6::run_grids(&opts, &gp, &sc);
        let stepped = table6::metrics_json(&opts, &m, &r).to_string_pretty();
        assert_eq!(
            serial, stepped,
            "step_threads={step_threads}: parallel-stepped cluster differs from serial"
        );
    }
}

/// Heterogeneous pools with migration enabled obey the same contract:
/// the migration grid's metric blocks are byte-identical across
/// `--threads` (cell sharding) and `--step-threads` (parallel engine
/// stepping) — relocations happen at interaction points in GPU order,
/// so parallel stepping adds no ordering freedom.
#[test]
fn heterogeneous_migration_grid_is_thread_invariant() {
    use step::sim::cluster::GpuProfile;
    let gp = GenParams::default_d64();
    let sc = projection_scorer(&gp);
    let base = ClusterOpts {
        gpus: 3,
        model: ModelId::Phi4_14B,
        bench: BenchId::Hmmt2425,
        n_requests: 6,
        clients: 3,
        think_s: 15.0,
        heavy_frac: 0.5,
        n_traces: 4,
        mem_util: 0.5,
        queue_cap: 0,
        max_outstanding: 1,
        gpu_profiles: GpuProfile::default_hetero(3),
        seed: 7,
        threads: 1,
        step_threads: 1,
        ..Default::default()
    };
    let fingerprint = table6::cells_fingerprint;
    let serial = fingerprint(&table6::run_migration_grid(&base, &gp, &sc));
    for threads in [2, 8] {
        let opts = ClusterOpts { threads, ..base.clone() };
        assert_eq!(
            serial,
            fingerprint(&table6::run_migration_grid(&opts, &gp, &sc)),
            "{threads}-thread migration grid differs from serial"
        );
    }
    for step_threads in [2, 4, 0] {
        let opts = ClusterOpts { step_threads, ..base.clone() };
        assert_eq!(
            serial,
            fingerprint(&table6::run_migration_grid(&opts, &gp, &sc)),
            "step_threads={step_threads}: migration grid differs from serial stepping"
        );
    }
}

/// Fleet scale: a 256-GPU closed-loop grid under the two-stage
/// `kv-sharded` router (16 shards at this R, so stage one genuinely
/// runs over multi-GPU aggregates, and debug builds cross-check every
/// incremental pick against the reference router) is byte-identical
/// across randomized `--threads` / `--step-threads` combinations, and
/// a rerun reproduces it exactly.
#[test]
fn fleet_scale_cluster_is_thread_invariant_at_r256() {
    use step::util::rng::Rng;
    let gp = GenParams::default_d64();
    let sc = projection_scorer(&gp);
    let base = ClusterOpts {
        gpus: 256,
        model: ModelId::Qwen3_4B,
        bench: BenchId::GpqaDiamond,
        n_requests: 32,
        clients: 16,
        think_s: 10.0,
        heavy_frac: 0.5,
        n_traces: 2,
        mem_util: 0.4,
        max_outstanding: 2,
        router: step::sim::router::RouterKind::KvPressureSharded,
        seed: 7,
        threads: 1,
        step_threads: 1,
        ..Default::default()
    };
    let fingerprint = table6::cells_fingerprint;
    let serial = fingerprint(&table6::run_migration_grid(&base, &gp, &sc));
    let mut rng = Rng::new(0xF1EE7);
    for _ in 0..3 {
        let opts = ClusterOpts {
            threads: 1 + rng.below(8),
            step_threads: rng.below(9), // 0 = all cores
            ..base.clone()
        };
        assert_eq!(
            serial,
            fingerprint(&table6::run_migration_grid(&opts, &gp, &sc)),
            "R=256 grid differs at threads={} step_threads={}",
            opts.threads,
            opts.step_threads
        );
    }
    // A rerun at the base settings reproduces the bytes too.
    assert_eq!(serial, fingerprint(&table6::run_migration_grid(&base, &gp, &sc)));
}

/// Elastic fleets under chaos obey the determinism contract too: with
/// a seeded random `FleetEvent` schedule firing joins, leaves, and spot
/// revocations mid-run (plus a standby pool the scaling controller can
/// activate), the cluster metric blocks stay byte-identical across
/// randomized `--threads` / `--step-threads` combinations, and a rerun
/// reproduces them exactly. Fleet-lifecycle transitions are control
/// events on the same clock as arrivals, applied serially between
/// engine-advance phases, so parallel stepping gains no ordering
/// freedom from engines appearing or disappearing.
#[test]
fn chaos_schedule_cluster_is_thread_invariant() {
    use step::util::rng::Rng;
    let gp = GenParams::default_d64();
    let sc = projection_scorer(&gp);
    let base = ClusterOpts {
        gpus: 4,
        model: ModelId::Phi4_14B,
        bench: BenchId::Hmmt2425,
        n_requests: 8,
        clients: 4,
        think_s: 20.0,
        heavy_frac: 0.5,
        n_traces: 4,
        mem_util: 0.5,
        fleet_events: "rand:9:6:240".to_string(),
        standby: 2,
        scale_up_queue_depth: 2,
        migrate: step::sim::cluster::MigrationPolicy::OnShed,
        seed: 7,
        threads: 1,
        step_threads: 1,
        ..Default::default()
    };
    let fingerprint = table6::cells_fingerprint;
    let serial = fingerprint(&table6::run_migration_grid(&base, &gp, &sc));
    let mut rng = Rng::new(0xC4A05);
    for _ in 0..3 {
        let opts = ClusterOpts {
            threads: 1 + rng.below(8),
            step_threads: rng.below(9), // 0 = all cores
            ..base.clone()
        };
        assert_eq!(
            serial,
            fingerprint(&table6::run_migration_grid(&opts, &gp, &sc)),
            "chaos grid differs at threads={} step_threads={}",
            opts.threads,
            opts.step_threads
        );
    }
    // A rerun at the base settings reproduces the bytes too.
    assert_eq!(serial, fingerprint(&table6::run_migration_grid(&base, &gp, &sc)));
}

/// Prefix-cache clusters obey the determinism contract too: the
/// affinity-weight sweep (no-cache baseline plus every weight, CoW
/// sharing and affinity-credited routing live) is byte-identical
/// across randomized `--threads` / `--step-threads` combinations, and
/// a rerun reproduces it exactly. Registry pins, CoW forks, and
/// evictions all happen inside per-GPU engines between interaction
/// points, so parallel stepping gains no ordering freedom from them.
#[test]
fn prefix_affinity_grid_is_thread_invariant() {
    use step::util::rng::Rng;
    let gp = GenParams::default_d64();
    let sc = projection_scorer(&gp);
    let base = ClusterOpts {
        gpus: 3,
        model: ModelId::Phi4_14B,
        bench: BenchId::Hmmt2425,
        n_requests: 8,
        clients: 4,
        think_s: 15.0,
        heavy_frac: 0.5,
        n_traces: 4,
        mem_util: 0.5,
        router: step::sim::router::RouterKind::KvPressureSharded,
        seed: 7,
        threads: 1,
        step_threads: 1,
        ..Default::default()
    };
    let fingerprint = |cells: &[table6::AffinityCell]| -> String {
        cells
            .iter()
            .map(|c| c.to_json().to_string_pretty())
            .collect::<Vec<_>>()
            .join("\n")
    };
    let serial = fingerprint(&table6::run_affinity_grid(&base, &gp, &sc));
    let mut rng = Rng::new(0xAF51);
    for _ in 0..3 {
        let opts = ClusterOpts {
            threads: 1 + rng.below(8),
            step_threads: rng.below(9), // 0 = all cores
            ..base.clone()
        };
        assert_eq!(
            serial,
            fingerprint(&table6::run_affinity_grid(&opts, &gp, &sc)),
            "affinity grid differs at threads={} step_threads={}",
            opts.threads,
            opts.step_threads
        );
    }
    // A rerun at the base settings reproduces the bytes too.
    assert_eq!(serial, fingerprint(&table6::run_affinity_grid(&base, &gp, &sc)));
}

/// The serve-sim acceptance contract: `--threads 1` and `--threads 8`
/// produce byte-identical BENCH_serving.json metric blocks. Threads only
/// shard the (deterministic, single-threaded) per-method simulations.
#[test]
fn serving_metric_blocks_are_thread_invariant() {
    let gp = GenParams::default_d64();
    let sc = projection_scorer(&gp);
    let base = ServingOpts {
        model: ModelId::Qwen3_4B,
        bench: BenchId::GpqaDiamond,
        n_requests: 4,
        rate_rps: 0.05,
        n_traces: 4,
        seed: 7,
        threads: 1,
        ..Default::default()
    };
    let serial = metrics_json(&base, &run_methods(&base, &gp, &sc)).to_string_pretty();
    for threads in [2, 8] {
        let opts = ServingOpts { threads, ..base.clone() };
        let sharded = metrics_json(&opts, &run_methods(&opts, &gp, &sc)).to_string_pretty();
        assert_eq!(serial, sharded, "{threads}-thread serving metrics differ from serial");
    }
}
