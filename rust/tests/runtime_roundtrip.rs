//! Integration: the AOT bridge end to end — load HLO text artifacts,
//! compile on the PJRT CPU client, execute, and cross-validate the two
//! scorer paths (HLO graph vs native rust MLP).
//!
//! Requires `make artifacts`; tests no-op (with a note) when absent so
//! `cargo test` stays runnable on a fresh checkout. The whole file needs
//! the `pjrt` feature (vendored `xla` crate).

#![cfg(feature = "pjrt")]

use step::coordinator::scorer::StepScorer;
use step::runtime::{Artifacts, DecodeExec, PrefillExec, Runtime, ScorerExec};
use step::util::rng::Rng;

fn runtime_or_skip() -> Option<Runtime> {
    let dir = Artifacts::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new(dir).expect("runtime"))
}

#[test]
fn scorer_hlo_matches_native_mlp() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let scorer_path = rt.artifacts.scorer_path("sim").unwrap();
    let native = StepScorer::from_json_file(&scorer_path).unwrap();
    let exec = ScorerExec::load(&mut rt, "sim", 8).unwrap();
    assert_eq!(exec.d, native.d);

    let mut rng = Rng::new(7);
    let h: Vec<f32> = (0..8 * native.d).map(|_| rng.normal() as f32).collect();
    let hlo_scores = exec.run(&h).unwrap();
    for b in 0..8 {
        let native_score = native.score(&h[b * native.d..(b + 1) * native.d]);
        assert!(
            (hlo_scores[b] - native_score).abs() < 1e-4,
            "lane {b}: hlo {} vs native {}",
            hlo_scores[b],
            native_score
        );
    }
}

#[test]
fn prefill_then_decode_is_consistent() {
    // Decoding token t at position p after prefilling tokens[..p] must
    // give the same logits as prefilling tokens[..p+1] (incremental
    // decoding correctness — the serving engine's core assumption).
    let Some(mut rt) = runtime_or_skip() else { return };
    let params = rt.param_literals().unwrap();
    let m = rt.artifacts.manifest.model;
    let prefill = PrefillExec::load(&mut rt, 1).unwrap();
    let decode = DecodeExec::load(&mut rt, 1).unwrap();

    // Prompt: BOS + a few digit tokens (conventions in model.py).
    let prompt = [1i32, 5, 9, 7, 6, 4];
    let p = prompt.len();

    // Reference: prefill the full prompt, read logits at last position.
    let mut padded = vec![0i32; m.prompt_len];
    padded[..p].copy_from_slice(&prompt);
    let (ref_logits, ref_hidden, _) = prefill.run(&params, &padded, &[p]).unwrap();

    // Incremental: prefill all but the last token, then decode it.
    let mut padded_short = vec![0i32; m.prompt_len];
    padded_short[..p - 1].copy_from_slice(&prompt[..p - 1]);
    let (_, _, kv) = prefill.run(&params, &padded_short, &[p - 1]).unwrap();
    let (dec_logits, dec_hidden, _) = decode
        .run(&params, &kv, &[prompt[p - 1]], &[(p - 1) as i32])
        .unwrap();

    let max_diff = ref_logits[0]
        .iter()
        .zip(&dec_logits[0])
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 2e-3, "decode/prefill logit divergence {max_diff}");
    let h_diff = ref_hidden[0]
        .iter()
        .zip(&dec_hidden[0])
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(h_diff < 2e-3, "hidden divergence {h_diff}");
}

#[test]
fn decode_steps_advance_kv() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let params = rt.param_literals().unwrap();
    let m = rt.artifacts.manifest.model;
    let prefill = PrefillExec::load(&mut rt, 1).unwrap();
    let decode = DecodeExec::load(&mut rt, 1).unwrap();

    let mut padded = vec![0i32; m.prompt_len];
    padded[0] = 1;
    padded[1] = 8;
    let (_, _, mut kv) = prefill.run(&params, &padded, &[2]).unwrap();
    let mut tok = 5i32;
    for i in 0..4 {
        let pos = (2 + i) as i32;
        let (logits, hidden, kv2) =
            decode.run(&params, &kv, &[tok], &[pos]).unwrap();
        assert_eq!(logits[0].len(), m.vocab);
        assert_eq!(hidden[0].len(), m.d_model);
        assert!(logits[0].iter().all(|x| x.is_finite()));
        // Greedy next token.
        tok = logits[0]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as i32;
        kv = kv2;
    }
}

#[test]
fn batched_prefill_lanes_independent() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let params = rt.param_literals().unwrap();
    let m = rt.artifacts.manifest.model;
    let p1 = PrefillExec::load(&mut rt, 1).unwrap();
    let p4 = PrefillExec::load(&mut rt, 4).unwrap();

    let prompts: Vec<Vec<i32>> = vec![
        vec![1, 5, 6],
        vec![1, 9, 9, 9, 4],
        vec![1, 7],
        vec![1, 4, 5, 6, 7, 8],
    ];
    let mut flat = vec![0i32; 4 * m.prompt_len];
    for (b, pr) in prompts.iter().enumerate() {
        flat[b * m.prompt_len..b * m.prompt_len + pr.len()].copy_from_slice(pr);
    }
    let lens: Vec<usize> = prompts.iter().map(|p| p.len()).collect();
    let (batch_logits, _, _) = p4.run(&params, &flat, &lens).unwrap();

    for (b, pr) in prompts.iter().enumerate() {
        let mut single = vec![0i32; m.prompt_len];
        single[..pr.len()].copy_from_slice(pr);
        let (one_logits, _, _) = p1.run(&params, &single, &[pr.len()]).unwrap();
        let diff = one_logits[0]
            .iter()
            .zip(&batch_logits[b])
            .map(|(a, c)| (a - c).abs())
            .fold(0.0f32, f32::max);
        assert!(diff < 2e-3, "lane {b} diverges by {diff}");
    }
}
