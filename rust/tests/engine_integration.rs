//! Integration tests over the full policy stack: the discrete-event
//! engine with the *trained* scorer from artifacts (skipped gracefully
//! when artifacts are absent), plus an e2e ServeEngine smoke over PJRT.

use step::coordinator::method::Method;
use step::coordinator::trace::TraceStatus;
use step::harness::cells::{run_cell, CellOpts};
use step::harness::load_sim_bundle;
use step::runtime::Artifacts;
use step::sim::des::{DesEngine, SimConfig};
use step::sim::profiles::{BenchId, ModelId};
use step::sim::tracegen::TraceGen;
use step::util::stats::auc;

fn bundle() -> Option<(step::sim::tracegen::GenParams, step::coordinator::scorer::StepScorer)> {
    let dir = Artifacts::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(load_sim_bundle(&dir).expect("sim bundle"))
}

#[test]
fn trained_scorer_separates_trace_quality() {
    let Some((gp, scorer)) = bundle() else { return };
    let gen = TraceGen::new(ModelId::Qwen3_4B, BenchId::Hmmt2425, gp, 3);
    let mut scores = Vec::new();
    let mut labels = Vec::new();
    for qid in 0..6 {
        let q = gen.question(qid);
        for i in 0..48 {
            let t = gen.trace(&q, i);
            // Mid-trace prefix: early steps are dominated by the
            // exploration transient (Fig 5's rising curve).
            let k = t.n_steps().min(150);
            let mut z = vec![0.0f32; scorer.hidden];
            let mean: f64 = (1..=k)
                .map(|n| scorer.score_into(&gen.hidden_state(&q, &t, n), &mut z) as f64)
                .sum::<f64>()
                / k as f64;
            scores.push(mean);
            labels.push(t.label);
        }
    }
    let a = auc(&scores, &labels).expect("both classes present");
    assert!(a > 0.78, "trained scorer AUC {a} too low");
}

#[test]
fn step_beats_sc_under_pressure_with_trained_scorer() {
    let Some((gp, scorer)) = bundle() else { return };
    let opts = CellOpts { n_traces: 64, max_questions: Some(6), ..Default::default() };
    let sc = run_cell(ModelId::DeepSeek8B, BenchId::Hmmt2425, Method::Sc, &gp, &scorer, &opts);
    let st = run_cell(ModelId::DeepSeek8B, BenchId::Hmmt2425, Method::Step, &gp, &scorer, &opts);
    assert!(st.lat_s < 0.7 * sc.lat_s, "STEP {:.0}s vs SC {:.0}s", st.lat_s, sc.lat_s);
    assert!(st.tok_k < sc.tok_k);
    assert_eq!(st.engine_wait_s, 0.0);
    assert!(sc.engine_wait_s > 0.0);
    assert!(st.acc >= sc.acc - 1.0, "STEP acc {} vs SC {}", st.acc, sc.acc);
}

#[test]
fn step_pruned_traces_skew_incorrect_with_trained_scorer() {
    let Some((gp, scorer)) = bundle() else { return };
    let mut cfg = SimConfig::new(ModelId::DeepSeek8B, BenchId::Hmmt2425, Method::Step, 64);
    cfg.seed = 5;
    let gen = TraceGen::new(cfg.model, cfg.bench, gp, 5);
    let engine = DesEngine::new(&cfg, &gen, &scorer);
    let (mut pr_inc, mut pr_all, mut base_inc, mut base_all) = (0, 0, 0, 0);
    for qid in 0..8 {
        let r = engine.run_question(qid);
        for t in &r.traces {
            base_all += 1;
            base_inc += (!t.label) as usize;
            if t.status == TraceStatus::Pruned {
                pr_all += 1;
                pr_inc += (!t.label) as usize;
            }
        }
    }
    assert!(pr_all > 20, "expected substantial pruning, got {pr_all}");
    let pruned_rate = pr_inc as f64 / pr_all as f64;
    let base_rate = base_inc as f64 / base_all as f64;
    assert!(
        pruned_rate > base_rate,
        "pruned traces must skew incorrect: {pruned_rate:.2} vs base {base_rate:.2}"
    );
}

#[test]
fn deepconf_early_stops_and_two_phase_latency() {
    let Some((gp, scorer)) = bundle() else { return };
    let opts = CellOpts { n_traces: 64, max_questions: Some(4), ..Default::default() };
    let r = run_cell(ModelId::DeepSeek8B, BenchId::Hmmt2425, Method::DeepConf, &gp, &scorer, &opts);
    let (warm, prune) = r.stage_lat.expect("deepconf reports stage latencies");
    assert!(warm > 0.0 && prune > 0.0);
    assert!((warm + prune - r.lat_s).abs() < 1e-6 * r.lat_s);
    assert!(r.tok_k < 1600.0, "deepconf must save tokens vs SC's ~2000k");
}

#[cfg(feature = "pjrt")]
#[test]
fn e2e_serve_smoke_over_pjrt() {
    use step::coordinator::engine::{ServeConfig, ServeEngine};
    use step::runtime::Runtime;
    let dir = Artifacts::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = Runtime::new(&dir).unwrap();
    let cfg = ServeConfig {
        n_traces: 4,
        method: Method::Step,
        max_new_tokens: 48,
        kv_blocks: 14,
        seed: 3,
        ..Default::default()
    };
    let engine = ServeEngine::new(rt, cfg).unwrap();
    let r = engine.serve("compute the sum 12+34 then answer", Some("46")).unwrap();
    assert!(r.generated_tokens > 0);
    assert!(r.decode_iterations > 0);
    assert!(r.latency_s > 0.0);
    assert_eq!(r.traces.len(), 4);
    // Every lane ended in a terminal state.
    for t in &r.traces {
        assert!(matches!(t.status, TraceStatus::Finished | TraceStatus::Pruned));
    }
    // Determinism of the serving path (same seed, same request).
    let r2 = engine.serve("compute the sum 12+34 then answer", Some("46")).unwrap();
    assert_eq!(r.generated_tokens, r2.generated_tokens);
    assert_eq!(r.answer, r2.answer);
}
