//! Observability contract tests over real cluster runs:
//!
//! * **determinism differential** — a traced run's metric block is
//!   byte-identical to the untraced run, for every `--step-threads`
//!   value, and the merged event stream itself is canonical (identical
//!   bytes for any engine-stepping thread count);
//! * **replay property** — `ClusterCounters` re-derived from the event
//!   stream alone reproduces the run's counters byte-for-byte
//!   (`report()` string equality), across seeds, migration policies,
//!   and fleet schedules;
//! * **JSONL round-trip** — `--trace-out` output parses back into the
//!   exact event stream, and kind filtering keeps only what it names;
//! * **Perfetto shape** — the `--perfetto-out` document is valid JSON
//!   with monotone timestamps, balanced `B`/`E` span pairs, and the
//!   queue-depth / KV-occupancy / live-traces counter tracks.

use std::collections::{HashMap, HashSet};

use step::coordinator::method::Method;
use step::harness::cells::projection_scorer;
use step::harness::table6::ClusterCell;
use step::obs::{parse_jsonl, perfetto, replay, to_jsonl};
use step::sim::cluster::{
    parse_fleet_events, ClusterConfig, ClusterResult, ClusterSim, ClusterWorkload,
    MigrationPolicy,
};
use step::sim::profiles::{BenchId, ModelId};
use step::sim::tracegen::{GenParams, TraceGen};
use step::sim::workload::ClosedLoopSpec;
use step::util::json::Json;

/// A pressured 3-GPU cluster (skewed closed loop, tight pool) so the
/// stream carries prunes, preemptions, queueing, and — under a
/// revoking schedule — drains and migration hops.
fn cfg(seed: u64, migration: MigrationPolicy, fleet: &str) -> ClusterConfig {
    ClusterConfig::builder(
        3,
        ModelId::Phi4_14B,
        BenchId::Hmmt2425,
        Method::Step,
        8,
        ClusterWorkload::Closed(ClosedLoopSpec::skewed(8, 30.0, 16, 0.5)),
    )
    .seed(seed)
    .mem_util(0.5)
    .migration(migration)
    .standby(1)
    .scale_up_queue_depth(2)
    .fleet_events(parse_fleet_events(fleet, 3, 1).expect("valid fleet spec"))
    .build()
}

fn run(cfg: &ClusterConfig) -> ClusterResult {
    let gp = GenParams::default_d64();
    let scorer = projection_scorer(&gp);
    let gen = TraceGen::new(cfg.model, cfg.bench, gp, cfg.seed ^ 0x5EED);
    ClusterSim::new(cfg, &gen, &scorer).run()
}

/// Recorders never influence scheduling: with the event log on, the
/// metric block stays byte-identical to the untraced run for every
/// engine-stepping thread count, and the merged stream itself is one
/// canonical byte sequence.
#[test]
fn traced_run_is_byte_identical_across_step_threads() {
    let fleet = "40:1:revoke:8;120:1:join";
    let base = run(&cfg(11, MigrationPolicy::OnShed, fleet));
    assert!(base.events.is_empty(), "untraced runs must record nothing");
    let base_row = ClusterCell::from_result("step", &base).to_json().to_string_pretty();
    let mut canonical_stream: Option<String> = None;
    for step_threads in [1usize, 2] {
        let mut c = cfg(11, MigrationPolicy::OnShed, fleet);
        c.event_log = Some(0);
        c.step_threads = step_threads;
        let r = run(&c);
        assert_eq!(
            ClusterCell::from_result("step", &r).to_json().to_string_pretty(),
            base_row,
            "step_threads={step_threads}: tracing changed the metric block"
        );
        assert!(!r.events.is_empty(), "step_threads={step_threads}");
        assert_eq!(r.events_dropped, 0, "the unbounded log never drops");
        let stream = to_jsonl(&r.events, &[]);
        match &canonical_stream {
            None => canonical_stream = Some(stream),
            Some(first) => assert_eq!(
                &stream, first,
                "step_threads={step_threads}: merged stream is not canonical"
            ),
        }
    }
}

/// The event stream is a faithful ledger: counters re-derived from
/// events alone reproduce the run's counters byte-for-byte, and the
/// per-request lifecycle/conservation laws hold — across seeds and
/// migration policies under a revoking schedule.
#[test]
fn replayed_counters_match_the_run_byte_for_byte() {
    for seed in [1u64, 5, 9] {
        for policy in [MigrationPolicy::Never, MigrationPolicy::OnShed] {
            let mut c = cfg(seed, policy, "30:0:revoke:10");
            c.event_log = Some(0);
            let r = run(&c);
            let label = format!("seed {seed} policy {}", policy.name());
            let report = replay::check(&r.events);
            assert!(report.ok(), "{label}: {:?}", report.violations);
            assert_eq!(
                report.counters.report(),
                r.counters.report(),
                "{label}: events do not replay the counters"
            );
        }
    }
}

/// A prefix-cache run is a first-class citizen of the ledger: the
/// stream carries `prefix-share` / `prefix-hit` / `prefix-evict`
/// events, the pin conservation law holds (every shared block pinned
/// and freed exactly once, hits only against live pins) even while the
/// fleet revokes a GPU mid-share, the counters replay byte-for-byte,
/// and the merged stream is canonical across step-thread counts.
#[test]
fn prefix_cache_traced_run_replays_and_conserves_pins() {
    let mut canonical: Option<ClusterResult> = None;
    for step_threads in [1usize, 2] {
        let mut c = cfg(13, MigrationPolicy::OnShed, "30:0:revoke:10");
        c.prefix_cache = true;
        c.affinity_weight = 0.5;
        c.event_log = Some(0);
        c.step_threads = step_threads;
        let r = run(&c);
        assert!(
            r.events.iter().any(|e| e.kind.name() == "prefix-share"),
            "shared admissions must be traced"
        );
        assert!(
            r.events.iter().any(|e| e.kind.name() == "prefix-hit"),
            "sibling traces of one question must hit the registry"
        );
        let report = replay::check(&r.events);
        assert!(report.ok(), "step_threads={step_threads}: {:?}", report.violations);
        assert_eq!(
            report.counters.report(),
            r.counters.report(),
            "step_threads={step_threads}: events do not replay the counters"
        );
        match &canonical {
            None => canonical = Some(r),
            Some(first) => {
                assert_eq!(
                    to_jsonl(&r.events, &[]),
                    to_jsonl(&first.events, &[]),
                    "step_threads={step_threads}: merged stream is not canonical"
                );
                assert_eq!(r.counters.report(), first.counters.report());
            }
        }
    }
}

/// `--trace-out` output round-trips: serialize, parse, same events;
/// a kind filter keeps exactly what it names.
#[test]
fn jsonl_round_trips_a_real_run_and_filters() {
    let mut c = cfg(2, MigrationPolicy::OnShed, "");
    c.event_log = Some(0);
    let r = run(&c);
    let text = to_jsonl(&r.events, &[]);
    assert_eq!(parse_jsonl(&text).expect("valid JSONL"), r.events);
    let filter = vec!["place".to_string(), "complete".to_string()];
    let filtered = parse_jsonl(&to_jsonl(&r.events, &filter)).expect("valid filtered JSONL");
    assert!(!filtered.is_empty(), "a real run places and completes requests");
    assert!(
        filtered.iter().all(|e| matches!(e.kind.name(), "place" | "complete")),
        "filter leaked other kinds"
    );
}

/// The Perfetto export of a real fixed-seed run: valid JSON, monotone
/// `ts`, every `B` span balanced by an `E` on the same track, and the
/// counter tracks the viewer renders are present.
#[test]
fn perfetto_export_has_a_valid_shape() {
    let mut c = cfg(3, MigrationPolicy::OnShed, "40:0:revoke:10");
    c.event_log = Some(0);
    let r = run(&c);
    let doc = perfetto::chrome_trace(&r.events);
    let back = Json::parse(&doc.to_string_compact()).expect("exporter emits valid JSON");
    let tes = back.get("traceEvents").as_arr().expect("traceEvents array");
    assert!(!tes.is_empty());
    let mut open: HashMap<(usize, String), i64> = HashMap::new();
    let mut counters: HashSet<String> = HashSet::new();
    let mut last = f64::NEG_INFINITY;
    for te in tes {
        let ph = te.get("ph").as_str().expect("ph");
        if ph == "M" {
            continue;
        }
        let ts = te.get("ts").as_f64().expect("ts");
        assert!(ts >= last, "ts runs backwards: {ts} < {last}");
        last = ts;
        let tid = te.get("tid").as_usize().expect("tid");
        let name = te.get("name").as_str().expect("name").to_string();
        match ph {
            "B" => *open.entry((tid, name)).or_insert(0) += 1,
            "E" => {
                let depth = open.get_mut(&(tid, name.clone())).unwrap_or_else(|| {
                    panic!("E without a B: tid {tid} name {name}")
                });
                *depth -= 1;
                assert!(*depth >= 0, "over-closed span: tid {tid} name {name}");
            }
            "C" => {
                counters.insert(name);
            }
            "i" => {}
            other => panic!("unexpected ph '{other}'"),
        }
    }
    assert!(
        open.values().all(|&d| d == 0),
        "unbalanced spans remain open: {open:?}"
    );
    assert!(counters.contains("queue_depth"), "missing queue_depth counter track");
    assert!(
        counters.iter().any(|n| n.starts_with("kv[g")),
        "missing KV-occupancy counter tracks: {counters:?}"
    );
    assert!(
        counters.iter().any(|n| n.starts_with("live[g")),
        "missing live-traces counter tracks: {counters:?}"
    );
}
