//! The signal-zoo API contract over real harness runs:
//!
//! * **default differential** — `--signal hidden-mlp` parsed through
//!   [`SignalSpec`] produces serving and cluster metric blocks
//!   byte-identical to the implicit default, across seeds and
//!   `--threads` / `--step-threads` values (the trait refactor's
//!   no-behavior-change lock, CLI-surface edition of the unit-level
//!   `hidden_mlp_matches_raw_scorer_path` test);
//! * **rival divergence** — every non-default signal actually changes
//!   the step-score sequence of a pressured STEP run (the zoo is not
//!   four names for one policy), while its event stream still replays
//!   cleanly and attributes every stamped event to the one selected
//!   signal;
//! * **rival determinism** — the determinism contract extends to the
//!   zoo: non-default signals are byte-identical across engine-stepping
//!   thread counts and reruns;
//! * **generator single-source** — `hidden_state` is bit-identical to
//!   `hidden_state_into` (the convenience wrapper may never drift from
//!   the hot path every signal reads through).

use step::coordinator::method::Method;
use step::coordinator::signal::SignalSpec;
use step::harness::cells::projection_scorer;
use step::harness::{table5, table6};
use step::obs::{replay, to_jsonl, EventKind, SimEvent};
use step::sim::cluster::{ClusterConfig, ClusterResult, ClusterSim, ClusterWorkload};
use step::sim::profiles::{BenchId, ModelId};
use step::sim::tracegen::{GenParams, TraceGen};
use step::sim::workload::ClosedLoopSpec;

/// A pressured 3-GPU STEP cluster (skewed closed loop, tight pool) with
/// the event log on, built through the config builder: enough memory
/// pressure that the signal's scores drive real victim selection.
fn traced_cfg(seed: u64, signal: SignalSpec) -> ClusterConfig {
    ClusterConfig::builder(
        3,
        ModelId::Phi4_14B,
        BenchId::Hmmt2425,
        Method::Step,
        8,
        ClusterWorkload::Closed(ClosedLoopSpec::skewed(8, 30.0, 16, 0.5)),
    )
    .seed(seed)
    .mem_util(0.5)
    .event_log(Some(0))
    .signal(signal)
    .build()
}

fn run(cfg: &ClusterConfig) -> ClusterResult {
    let gp = GenParams::default_d64();
    let scorer = projection_scorer(&gp);
    let gen = TraceGen::new(cfg.model, cfg.bench, gp, cfg.seed ^ 0x5EED);
    ClusterSim::new(cfg, &gen, &scorer).run()
}

/// The step-score sequence of an event stream, in merge order.
fn step_scores(events: &[SimEvent]) -> Vec<f64> {
    events
        .iter()
        .filter_map(|e| match e.kind {
            EventKind::StepScore { score } => Some(score),
            _ => None,
        })
        .collect()
}

/// Parsing `hidden-mlp` through the `--signal` surface is the implicit
/// default: serving and cluster metric blocks are byte-identical, for
/// every thread count — so turning the scorer into the default
/// `TraceSignal` implementation changed no observable output.
#[test]
fn explicit_hidden_mlp_matches_the_default_byte_for_byte() {
    let gp = GenParams::default_d64();
    let sc = projection_scorer(&gp);
    let explicit = SignalSpec::parse("hidden-mlp").expect("the default signal parses");
    assert_eq!(explicit, SignalSpec::default(), "parse('hidden-mlp') must be Default");

    let serve_base = table5::ServingOpts {
        model: ModelId::Qwen3_4B,
        bench: BenchId::GpqaDiamond,
        n_requests: 4,
        rate_rps: 0.05,
        n_traces: 4,
        seed: 7,
        threads: 1,
        ..Default::default()
    };
    let serve = |opts: &table5::ServingOpts| -> String {
        table5::metrics_json(opts, &table5::run_methods(opts, &gp, &sc)).to_string_pretty()
    };
    let default_block = serve(&serve_base);
    for threads in [1usize, 4] {
        let opts = table5::ServingOpts {
            signal: explicit.clone(),
            threads,
            ..serve_base.clone()
        };
        assert_eq!(
            serve(&opts),
            default_block,
            "threads={threads}: explicit --signal hidden-mlp changed the serving block"
        );
    }

    let cluster_base = table6::ClusterOpts {
        gpus: 2,
        model: ModelId::Qwen3_4B,
        bench: BenchId::GpqaDiamond,
        n_requests: 4,
        clients: 2,
        think_s: 20.0,
        n_traces: 4,
        mem_util: 0.5,
        seed: 7,
        threads: 1,
        step_threads: 1,
        ..Default::default()
    };
    let cluster = |opts: &table6::ClusterOpts| -> String {
        let (m, r) = table6::run_grids(opts, &gp, &sc);
        table6::metrics_json(opts, &m, &r).to_string_pretty()
    };
    for seed in [7u64, 11] {
        let base = table6::ClusterOpts { seed, ..cluster_base.clone() };
        let default_block = cluster(&base);
        for (threads, step_threads) in [(1usize, 2usize), (4, 0)] {
            let opts = table6::ClusterOpts {
                signal: explicit.clone(),
                threads,
                step_threads,
                ..base.clone()
            };
            assert_eq!(
                cluster(&opts),
                default_block,
                "seed={seed} threads={threads} step_threads={step_threads}: \
                 explicit --signal hidden-mlp changed the cluster block"
            );
        }
    }
}

/// Every rival signal really is a different scoring policy: under the
/// same pressured STEP schedule its step-score sequence diverges from
/// the hidden-MLP default — while its event stream still satisfies the
/// lifecycle/conservation laws and every stamped step-score event is
/// attributed to exactly the selected signal.
#[test]
fn rival_signals_diverge_from_the_default_and_replay_cleanly() {
    let base = run(&traced_cfg(11, SignalSpec::default()));
    let base_scores = step_scores(&base.events);
    assert!(!base_scores.is_empty(), "a pressured STEP run must score boundaries");

    for name in ["latent-temporal", "confidence", "prm-oracle"] {
        let spec = SignalSpec::parse(name).expect("zoo names parse");
        let r = run(&traced_cfg(11, spec));
        let scores = step_scores(&r.events);
        assert!(!scores.is_empty(), "{name}: no step boundaries scored");
        assert_ne!(
            scores, base_scores,
            "{name}: rival scores are bit-identical to hidden-mlp"
        );

        let report = replay::check(&r.events);
        assert!(report.ok(), "{name}: {:?}", report.violations);
        assert_eq!(
            report.counters.report(),
            r.counters.report(),
            "{name}: events do not replay the counters"
        );
        assert_eq!(
            report.attribution.len(),
            1,
            "{name}: one signal ran, one attribution row expected ({:?})",
            report.attribution
        );
        let a = &report.attribution[0];
        assert_eq!(a.signal, name, "stamps must carry the selected signal");
        assert_eq!(
            a.step_scores,
            scores.len() as u64,
            "{name}: every step-score event is stamped"
        );
        let prune_events =
            r.events.iter().filter(|e| matches!(e.kind, EventKind::Prune)).count() as u64;
        assert!(
            a.prunes <= prune_events,
            "{name}: attributed prunes exceed prune events"
        );
    }
}

/// The determinism contract extends to the zoo: a non-default signal's
/// traced run is byte-identical (metric report and merged event stream)
/// across engine-stepping thread counts, and a rerun reproduces it.
#[test]
fn rival_signal_runs_are_step_thread_invariant() {
    for name in ["latent-temporal", "confidence"] {
        let spec = SignalSpec::parse(name).expect("zoo names parse");
        let base = run(&traced_cfg(13, spec.clone()));
        let base_stream = to_jsonl(&base.events, &[]);
        for step_threads in [2usize, 0] {
            let mut cfg = traced_cfg(13, spec.clone());
            cfg.step_threads = step_threads;
            let r = run(&cfg);
            assert_eq!(
                r.counters.report(),
                base.counters.report(),
                "{name} step_threads={step_threads}: counters differ from serial"
            );
            assert_eq!(
                to_jsonl(&r.events, &[]),
                base_stream,
                "{name} step_threads={step_threads}: merged stream is not canonical"
            );
        }
        let rerun = run(&traced_cfg(13, spec));
        assert_eq!(to_jsonl(&rerun.events, &[]), base_stream, "{name}: rerun diverged");
    }
}

/// The signal selection lands in the serving config block: the
/// `--signal` spec string is serialized so an artifact records which
/// signal produced it.
#[test]
fn serving_config_block_records_the_signal_spec() {
    let gp = GenParams::default_d64();
    let sc = projection_scorer(&gp);
    let opts = table5::ServingOpts {
        model: ModelId::Qwen3_4B,
        bench: BenchId::GpqaDiamond,
        n_requests: 2,
        n_traces: 4,
        seed: 7,
        threads: 1,
        signal: SignalSpec::parse("confidence:gamma=2").expect("valid spec"),
        ..Default::default()
    };
    let block = table5::metrics_json(&opts, &table5::run_methods(&opts, &gp, &sc))
        .to_string_pretty();
    assert!(
        block.contains("\"signal\": \"confidence:gamma=2\""),
        "config block must record the signal spec string: {block}"
    );
}

/// `hidden_state` is the convenience wrapper over `hidden_state_into`
/// and may never drift from it: both produce bit-identical vectors for
/// every (question, trace, boundary), including into a dirty reused
/// buffer.
#[test]
fn hidden_state_wrapper_is_bit_identical_to_the_hot_path() {
    let gp = GenParams::default_d64();
    let g = TraceGen::new(ModelId::Qwen3_4B, BenchId::Aime25, gp.clone(), 42);
    let mut buf = vec![0.0f32; gp.d];
    for qid in 0..3 {
        let q = g.question(qid);
        for i in 0..4 {
            let t = g.trace(&q, i);
            for n in 1..=t.n_steps().min(6) {
                let fresh = g.hidden_state(&q, &t, n);
                buf.iter_mut().for_each(|x| *x = f32::NAN); // dirty the buffer
                g.hidden_state_into(&q, &t, n, &mut buf);
                assert_eq!(
                    fresh, buf,
                    "q{qid} trace {i} step {n}: wrapper drifted from hidden_state_into"
                );
            }
        }
    }
}
