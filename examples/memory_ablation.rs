//! Memory-pressure ablation (extends the paper's Table 4): sweep the GPU
//! memory utilization knob and watch each method's behaviour change —
//! SC's waiting time explodes as the budget shrinks while STEP's
//! accuracy holds because its scorer identifies winners early (§5.3.5).
//!
//!     cargo run --release --example memory_ablation

use step::coordinator::method::Method;
use step::harness::cells::{run_cell, CellOpts};
use step::harness::{artifact_dir, load_sim_bundle};
use step::sim::profiles::{BenchId, ModelId};

fn main() -> anyhow::Result<()> {
    let (gen_params, scorer) = load_sim_bundle(&artifact_dir())?;
    let questions = Some(15);

    println!("GPU-memory ablation: DeepSeek-8B / HMMT-25 / N=32\n");
    println!(
        "{:>5} | {:<8} | {:>6} {:>8} {:>8} {:>9} {:>7}",
        "util", "method", "acc%", "lat(s)", "wait(s)", "preempts", "pruned"
    );
    for util in [0.5, 0.6, 0.7, 0.8, 0.9] {
        for method in [Method::Sc, Method::Step] {
            let opts = CellOpts {
                n_traces: 32,
                max_questions: questions,
                mem_util: util,
                ..Default::default()
            };
            let r = run_cell(
                ModelId::DeepSeek8B,
                BenchId::Hmmt2425,
                method,
                &gen_params,
                &scorer,
                &opts,
            );
            println!(
                "{:>5.1} | {:<8} | {:>6.1} {:>8.0} {:>8.0} {:>9.1} {:>7.1}",
                util,
                method.name(),
                r.acc,
                r.lat_s,
                r.engine_wait_s,
                r.n_preemptions,
                r.n_pruned,
            );
        }
    }
    println!("\nexpected shape: SC wait grows as util shrinks; STEP wait stays 0");
    println!("and its accuracy is flat across budgets (paper: 70.1 +/- 1.8).");
    Ok(())
}
