//! End-to-end serving driver (the repro brief's mandated example): load
//! the REAL AOT-compiled tiny transformer through PJRT and serve batched
//! requests through the full STEP stack — rust router/scheduler -> paged
//! KV accounting -> jax-lowered decode graph containing the Pallas
//! decode-attention kernel -> Pallas scorer graph -> memory-triggered
//! pruning -> score-weighted voting. Reports latency and throughput.
//!
//! No simulation on this path: every token comes out of XLA. Results are
//! recorded in EXPERIMENTS.md §E2E.
//!
//! This driver serves requests one at a time through the real model; its
//! simulated sibling is `step serve-sim` (rust/src/sim/serve.rs), which
//! runs *concurrent* requests with continuous batching against one
//! shared KV pool and reports throughput + p50/p95/p99 SLOs. Porting
//! that multi-request scheduler (and its coordinator::request
//! lifecycle) onto this PJRT backend is the natural next step for the
//! e2e path.
//!
//!     make artifacts && cargo run --release --example e2e_serve

use step::coordinator::engine::{ServeConfig, ServeEngine};
use step::coordinator::method::Method;
use step::runtime::{Artifacts, Runtime};
use step::util::stats::{mean, percentile};

fn main() -> anyhow::Result<()> {
    let dir = Artifacts::default_dir();
    if !dir.join("manifest.json").exists() {
        anyhow::bail!("artifacts not built — run `make artifacts` first");
    }

    // A small synthetic arithmetic workload: the tiny LM is random-init,
    // so answers are noise; the point is the full serving path + the
    // policy mechanics under a real model at real (CPU) latencies.
    let requests: Vec<(String, String)> = (0..4)
        .map(|i| {
            let a = 17 + 3 * i;
            let b = 25 + 7 * i;
            (format!("compute the sum {a}+{b} then answer"), format!("{}", a + b))
        })
        .collect();

    for method in [Method::Sc, Method::Step] {
        let rt = Runtime::new(&dir)?;
        let cfg = ServeConfig {
            n_traces: 8,
            method,
            max_new_tokens: 96,
            // Small virtual budget so the §4.2 memory trigger fires:
            // 8 lanes x (prompt + 96 tokens) wants ~56 blocks; give 26.
            kv_blocks: 26,
            seed: 7,
            ..Default::default()
        };
        let engine = ServeEngine::new(rt, cfg)?;

        println!("\n=== method: {} ===", method.name());
        let mut lat = Vec::new();
        let mut tps = Vec::new();
        let mut total_pruned = 0;
        for (i, (prompt, gt)) in requests.iter().enumerate() {
            let r = engine.serve(prompt, Some(gt))?;
            lat.push(r.latency_s);
            tps.push(r.tokens_per_second());
            total_pruned += r.pruned;
            println!(
                "req {i}: latency={:.2}s prefill={:.2}s decode={:.2}s scoring={:.3}s \
                 tokens={} iters={} pruned={} answer={:?}",
                r.latency_s,
                r.prefill_s,
                r.decode_s,
                r.scoring_s,
                r.generated_tokens,
                r.decode_iterations,
                r.pruned,
                r.answer
            );
            for (ti, t) in r.traces.iter().enumerate() {
                println!(
                    "    trace {ti}: {:?} gen={} steps_scored={} score={:.3} ans={:?}",
                    t.status, t.generated, t.steps_scored, t.final_score, t.answer
                );
            }
        }
        println!(
            "summary[{}]: mean latency {:.2}s  p95 {:.2}s  mean throughput {:.0} tok/s  pruned {}",
            method.name(),
            mean(&lat),
            percentile(&lat, 95.0),
            mean(&tps),
            total_pruned
        );
    }
    println!("\nall layers composed: PJRT decode graph (with Pallas attention kernel),");
    println!("Pallas scorer graph, paged-KV accounting, memory-triggered pruning, voting.");
    Ok(())
}
