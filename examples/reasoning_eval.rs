//! Benchmark-evaluation driver: the workload the paper's intro motivates
//! — run a full reasoning benchmark under a trace budget and compare all
//! five methods on accuracy / tokens / end-to-end latency.
//!
//!     cargo run --release --example reasoning_eval -- [bench] [model] [N]
//!
//! e.g. `cargo run --release --example reasoning_eval -- hmmt deepseek 32`

use step::coordinator::method::Method;
use step::harness::cells::{run_cell, CellOpts};
use step::harness::{artifact_dir, load_sim_bundle};
use step::sim::profiles::{BenchId, ModelId};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let bench = args
        .first()
        .and_then(|s| BenchId::parse(s))
        .unwrap_or(BenchId::Aime25);
    let model = args
        .get(1)
        .and_then(|s| ModelId::parse(s))
        .unwrap_or(ModelId::Qwen3_4B);
    let n_traces: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(32);

    let (gen_params, scorer) = load_sim_bundle(&artifact_dir())?;
    println!("evaluating {} on {:?} with N={n_traces}\n", bench.name(), model);
    println!(
        "{:<10} | {:>6} {:>9} {:>8} {:>8} {:>8}",
        "method", "acc%", "tokens(k)", "lat(s)", "wait(s)", "pruned"
    );
    let mut baseline_lat = None;
    for method in Method::ALL {
        let opts = CellOpts { n_traces, ..Default::default() };
        let r = run_cell(model, bench, method, &gen_params, &scorer, &opts);
        if method == Method::Sc {
            baseline_lat = Some(r.lat_s);
        }
        let speedup = baseline_lat
            .map(|b| format!("  ({:.1}x vs SC)", b / r.lat_s))
            .unwrap_or_default();
        println!(
            "{:<10} | {:>6.1} {:>9.1} {:>8.0} {:>8.0} {:>8.1}{speedup}",
            method.name(),
            r.acc,
            r.tok_k,
            r.lat_s,
            r.engine_wait_s,
            r.n_pruned,
        );
    }
    Ok(())
}
