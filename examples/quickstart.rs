//! Quickstart: the STEP policy stack in thirty lines.
//!
//! Runs one simulated question under self-consistency and under STEP on
//! the same (model, benchmark) cell and prints the comparison the paper
//! is about: same-or-better answer quality, far lower latency, zero
//! waiting.
//!
//!     cargo run --release --example quickstart

use step::coordinator::method::Method;
use step::harness::{artifact_dir, load_sim_bundle};
use step::sim::des::{DesEngine, SimConfig};
use step::sim::profiles::{BenchId, ModelId};
use step::sim::tracegen::TraceGen;

fn main() -> anyhow::Result<()> {
    let (gen_params, scorer) = load_sim_bundle(&artifact_dir())?;

    for method in [Method::Sc, Method::Step] {
        let cfg = SimConfig::new(ModelId::DeepSeek8B, BenchId::Aime25, method, 64);
        let gen = TraceGen::new(cfg.model, cfg.bench, gen_params.clone(), 42);
        let engine = DesEngine::new(&cfg, &gen, &scorer);
        let r = engine.run_question(7);
        println!(
            "{:<4}  answer_correct={:<5}  tokens={:>6.0}k  latency={:>6.0}s  \
             wait={:>5.0}s  preemptions={:<3} pruned={}",
            method.name(),
            r.correct,
            r.gen_tokens as f64 / 1000.0,
            r.latency_s,
            r.engine_wait_s,
            r.n_preemptions,
            r.n_pruned,
        );
    }
    println!("\nSTEP prunes the weakest traces the moment GPU memory saturates,");
    println!("so nothing ever queues — that is the whole paper.");
    Ok(())
}
