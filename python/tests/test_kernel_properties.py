"""Deeper kernel properties beyond allclose-vs-ref: invariances the
serving engine relies on (permutation equivariance across batch lanes,
length monotonicity, scale behavior) plus failure-path checks."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.attention import decode_attention
from compile.kernels.scorer import scorer_mlp

SETTINGS = dict(max_examples=15, deadline=None)


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


@hypothesis.given(seed=st.integers(0, 2**31 - 1))
@hypothesis.settings(**SETTINGS)
def test_attention_batch_permutation_equivariance(seed):
    """Shuffling lanes shuffles outputs identically — no cross-lane leak."""
    rng = np.random.default_rng(seed)
    b, h, m, dh = 4, 2, 64, 32
    q = rand(rng, b, h, dh)
    k = rand(rng, b, h, m, dh)
    v = rand(rng, b, h, m, dh)
    lens = jnp.asarray(rng.integers(1, m + 1, size=b), jnp.int32)
    perm = rng.permutation(b)
    out = np.asarray(decode_attention(q, k, v, lens, block_k=32))
    out_p = np.asarray(
        decode_attention(q[perm], k[perm], v[perm], lens[perm], block_k=32))
    np.testing.assert_allclose(out[perm], out_p, rtol=1e-6, atol=1e-6)


@hypothesis.given(seed=st.integers(0, 2**31 - 1))
@hypothesis.settings(**SETTINGS)
def test_attention_is_convex_combination(seed):
    """Output lies in the convex hull of valid V rows: max|out| <=
    max|v_valid| per (b, h, d) column."""
    rng = np.random.default_rng(seed)
    b, h, m, dh = 2, 2, 64, 16
    q = rand(rng, b, h, dh, scale=3.0)
    k = rand(rng, b, h, m, dh)
    v = rand(rng, b, h, m, dh)
    lens = jnp.asarray(rng.integers(1, m + 1, size=b), jnp.int32)
    out = np.asarray(decode_attention(q, k, v, lens, block_k=32))
    vv = np.asarray(v)
    for bi in range(b):
        valid = vv[bi, :, : int(lens[bi])]
        lo = valid.min(axis=1) - 1e-5
        hi = valid.max(axis=1) + 1e-5
        assert (out[bi] >= lo).all() and (out[bi] <= hi).all()


def test_attention_uniform_when_keys_equal():
    """Identical keys => attention is the mean of valid values."""
    rng = np.random.default_rng(0)
    b, h, m, dh = 1, 1, 32, 8
    q = rand(rng, b, h, dh)
    k = jnp.broadcast_to(rand(rng, 1, 1, 1, dh), (b, h, m, dh))
    v = rand(rng, b, h, m, dh)
    lens = jnp.asarray([20], jnp.int32)
    out = np.asarray(decode_attention(q, k, v, lens, block_k=32))[0, 0]
    expect = np.asarray(v)[0, 0, :20].mean(axis=0)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)


@hypothesis.given(seed=st.integers(0, 2**31 - 1))
@hypothesis.settings(**SETTINGS)
def test_scorer_batch_permutation_equivariance(seed):
    rng = np.random.default_rng(seed)
    b, d, hm = 16, 32, 64
    h = rand(rng, b, d)
    w1 = rand(rng, d, hm, scale=0.2)
    b1 = rand(rng, hm, scale=0.1)
    w2 = rand(rng, hm, 1, scale=0.2)
    b2 = rand(rng, 1)
    perm = rng.permutation(b)
    out = np.asarray(scorer_mlp(h, w1, b1, w2, b2, block_b=8))
    out_p = np.asarray(scorer_mlp(h[perm], w1, b1, w2, b2, block_b=8))
    np.testing.assert_allclose(out[perm], out_p, rtol=1e-6, atol=1e-7)


def test_scorer_monotone_along_positive_direction():
    """With non-negative weights, increasing h increases the score."""
    d, hm = 8, 16
    w1 = jnp.ones((d, hm), jnp.float32) * 0.1
    b1 = jnp.zeros((hm,), jnp.float32)
    w2 = jnp.ones((hm, 1), jnp.float32) * 0.1
    b2 = jnp.zeros((1,), jnp.float32)
    h_lo = jnp.zeros((1, d), jnp.float32)
    h_hi = jnp.ones((1, d), jnp.float32)
    s_lo = float(scorer_mlp(h_lo, w1, b1, w2, b2)[0])
    s_hi = float(scorer_mlp(h_hi, w1, b1, w2, b2)[0])
    assert s_hi > s_lo


def test_scorer_rejects_ragged_batch():
    rng = np.random.default_rng(1)
    h = rand(rng, 12, 8)  # 12 not a multiple of block_b=8
    w1 = rand(rng, 8, 16)
    with pytest.raises(ValueError, match="block_b"):
        scorer_mlp(h, w1, jnp.zeros(16), rand(rng, 16, 1), jnp.zeros(1),
                   block_b=8)


def test_ref_and_kernel_agree_on_single_position_cache():
    """Minimum cache (M=block) — boundary condition of the tiling."""
    rng = np.random.default_rng(2)
    q = rand(rng, 1, 1, 16)
    k = rand(rng, 1, 1, 32, 16)
    v = rand(rng, 1, 1, 32, 16)
    lens = jnp.asarray([32], jnp.int32)
    out = decode_attention(q, k, v, lens, block_k=32)
    exp = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-6)
