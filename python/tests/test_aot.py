"""AOT lowering path: HLO text validity, manifest integrity, param export."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M

CFG = M.ModelConfig(vocab=64, d_model=32, n_layers=2, n_heads=2, d_ff=64,
                    max_len=32)


def test_scorer_hlo_text_parses_as_entry():
    text = aot.lower_scorer(16, 4, hidden=32)
    assert "ENTRY" in text
    assert "HloModule" in text


def _entry_param_count(text):
    entry = text[text.index("ENTRY"):]
    entry = entry[:entry.index("\n}")]
    return entry.count("parameter(")


def test_decode_hlo_text_small_model():
    text = aot.lower_decode(CFG, 2)
    assert "ENTRY" in text
    # 14 params + kv + token + pos = 17 parameters in the entry computation.
    assert _entry_param_count(text) == 17


def test_prefill_hlo_text_small_model():
    text = aot.lower_prefill(CFG, 1)
    assert "ENTRY" in text
    assert _entry_param_count(text) == 15


def test_param_specs_match_init():
    p = M.init_params(CFG)
    specs = aot.param_specs(CFG)
    assert len(specs) == len(p)
    for (name, spec), arr in zip(specs, p):
        assert tuple(spec.shape) == arr.shape, name
        assert spec.dtype == arr.dtype, name


def test_export_params_layout(tmp_path):
    path = tmp_path / "params.bin"
    entries = aot.export_params(CFG, str(path))
    raw = np.fromfile(path, dtype="<f4")
    total = sum(e["len"] for e in entries)
    assert len(raw) == total
    # Offsets are contiguous and ordered.
    off = 0
    for e in entries:
        assert e["offset"] == off
        off += e["len"]
    # Spot-check the embed slab round-trips the init values.
    p = M.init_params(CFG)
    e0 = entries[0]
    np.testing.assert_array_equal(
        raw[e0["offset"]:e0["offset"] + e0["len"]],
        np.asarray(p.embed, np.float32).flatten())


def test_fingerprint_stable():
    assert aot.input_fingerprint() == aot.input_fingerprint()
    assert len(aot.input_fingerprint()) == 16


@pytest.mark.skipif(not os.path.exists(
    os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built")
def test_built_manifest_consistent():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(root, "manifest.json")) as f:
        man = json.load(f)
    for name, g in man["graphs"].items():
        path = os.path.join(root, g["file"])
        assert os.path.exists(path), name
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head, name
    raw = np.fromfile(os.path.join(root, man["params_bin"]), dtype="<f4")
    assert len(raw) == sum(e["len"] for e in man["params"])
