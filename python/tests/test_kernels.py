"""L1 Pallas kernels vs pure-jnp oracles — the core compile-path signal.

Hypothesis sweeps shapes/dtypes per the repro brief; each kernel must match
its ref to float tolerance for every generated configuration.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.attention import decode_attention
from compile.kernels.scorer import scorer_mlp

SETTINGS = dict(max_examples=25, deadline=None,
                suppress_health_check=[hypothesis.HealthCheck.too_slow])


def rand(rng, *shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


# ---------------------------------------------------------------- attention

@hypothesis.given(
    b=st.integers(1, 5),
    h=st.integers(1, 4),
    m_blocks=st.integers(1, 4),
    dh=st.sampled_from([16, 32, 64]),
    block_k=st.sampled_from([32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
@hypothesis.settings(**SETTINGS)
def test_decode_attention_matches_ref(b, h, m_blocks, dh, block_k, seed):
    m = m_blocks * block_k
    rng = np.random.default_rng(seed)
    q = rand(rng, b, h, dh)
    k = rand(rng, b, h, m, dh)
    v = rand(rng, b, h, m, dh)
    lens = jnp.asarray(rng.integers(1, m + 1, size=b), jnp.int32)
    out = decode_attention(q, k, v, lens, block_k=block_k)
    exp = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5), (jnp.bfloat16, 2e-2)])
def test_decode_attention_dtypes(dtype, tol):
    rng = np.random.default_rng(0)
    b, h, m, dh = 2, 4, 128, 64
    q = rand(rng, b, h, dh, dtype=dtype)
    k = rand(rng, b, h, m, dh, dtype=dtype)
    v = rand(rng, b, h, m, dh, dtype=dtype)
    lens = jnp.asarray([17, 128], jnp.int32)
    out = decode_attention(q, k, v, lens, block_k=64)
    exp = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), rtol=tol, atol=tol)


def test_decode_attention_len_one_is_value():
    """With one valid position, attention must return v[:, :, 0] exactly."""
    rng = np.random.default_rng(1)
    b, h, m, dh = 3, 2, 64, 32
    q = rand(rng, b, h, dh)
    k = rand(rng, b, h, m, dh)
    v = rand(rng, b, h, m, dh)
    lens = jnp.ones((b,), jnp.int32)
    out = decode_attention(q, k, v, lens, block_k=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(v[:, :, 0, :]),
                               rtol=1e-6, atol=1e-6)


def test_decode_attention_ignores_padding_garbage():
    """Positions >= lens must not influence the output at all."""
    rng = np.random.default_rng(2)
    b, h, m, dh = 2, 2, 128, 32
    q = rand(rng, b, h, dh)
    k = rand(rng, b, h, m, dh)
    v = rand(rng, b, h, m, dh)
    lens = jnp.asarray([40, 70], jnp.int32)
    out1 = decode_attention(q, k, v, lens, block_k=64)
    k2 = k.at[:, :, 90:, :].set(1e6)
    v2 = v.at[:, :, 90:, :].set(-1e6)
    out2 = decode_attention(q, k2, v2, lens, block_k=64)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_decode_attention_block_size_invariance():
    """The online-softmax accumulation must be block-size independent."""
    rng = np.random.default_rng(3)
    b, h, m, dh = 2, 3, 256, 64
    q = rand(rng, b, h, dh)
    k = rand(rng, b, h, m, dh)
    v = rand(rng, b, h, m, dh)
    lens = jnp.asarray([100, 256], jnp.int32)
    outs = [np.asarray(decode_attention(q, k, v, lens, block_k=bk))
            for bk in (32, 64, 128, 256)]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=1e-5, atol=1e-6)


def test_decode_attention_rejects_misaligned_cache():
    rng = np.random.default_rng(4)
    q = rand(rng, 1, 1, 16)
    k = rand(rng, 1, 1, 100, 16)
    v = rand(rng, 1, 1, 100, 16)
    with pytest.raises(ValueError, match="block_k"):
        decode_attention(q, k, v, jnp.asarray([5], jnp.int32), block_k=64)


def test_decode_attention_numerically_extreme_logits():
    """Large-magnitude K must not overflow the online softmax."""
    rng = np.random.default_rng(5)
    b, h, m, dh = 1, 1, 64, 32
    q = rand(rng, b, h, dh, scale=30.0)
    k = rand(rng, b, h, m, dh, scale=30.0)
    v = rand(rng, b, h, m, dh)
    lens = jnp.asarray([64], jnp.int32)
    out = np.asarray(decode_attention(q, k, v, lens, block_k=32))
    exp = np.asarray(ref.decode_attention_ref(q, k, v, lens))
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------- scorer

@hypothesis.given(
    b_tiles=st.integers(1, 3),
    block_b=st.sampled_from([8, 16, 64]),
    d=st.sampled_from([16, 64, 256]),
    hm=st.sampled_from([32, 512]),
    seed=st.integers(0, 2**31 - 1),
)
@hypothesis.settings(**SETTINGS)
def test_scorer_mlp_matches_ref(b_tiles, block_b, d, hm, seed):
    b = b_tiles * block_b
    rng = np.random.default_rng(seed)
    h = rand(rng, b, d)
    w1 = rand(rng, d, hm, scale=d**-0.5)
    b1 = rand(rng, hm, scale=0.1)
    w2 = rand(rng, hm, 1, scale=hm**-0.5)
    b2 = rand(rng, 1, scale=0.1)
    out = scorer_mlp(h, w1, b1, w2, b2, block_b=block_b)
    exp = ref.scorer_mlp_ref(h, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-6)


def test_scorer_mlp_small_batch_single_tile():
    """B < block_b must fall back to a single-tile launch."""
    rng = np.random.default_rng(7)
    h = rand(rng, 3, 64)
    w1 = rand(rng, 64, 512, scale=0.1)
    b1 = jnp.zeros((512,), jnp.float32)
    w2 = rand(rng, 512, 1, scale=0.05)
    b2 = jnp.zeros((1,), jnp.float32)
    out = scorer_mlp(h, w1, b1, w2, b2, block_b=64)
    exp = ref.scorer_mlp_ref(h, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-6)


def test_scorer_mlp_outputs_are_probabilities():
    rng = np.random.default_rng(8)
    h = rand(rng, 64, 64, scale=10.0)
    w1 = rand(rng, 64, 512)
    b1 = rand(rng, 512)
    w2 = rand(rng, 512, 1)
    b2 = rand(rng, 1)
    out = np.asarray(scorer_mlp(h, w1, b1, w2, b2))
    assert ((out >= 0.0) & (out <= 1.0)).all()


def test_scorer_mlp_bf16_hidden_states():
    rng = np.random.default_rng(9)
    h = rand(rng, 8, 64, dtype=jnp.bfloat16)
    w1 = rand(rng, 64, 512, scale=0.1)
    b1 = jnp.zeros((512,), jnp.float32)
    w2 = rand(rng, 512, 1, scale=0.05)
    b2 = jnp.zeros((1,), jnp.float32)
    out = scorer_mlp(h, w1, b1, w2, b2)
    exp = ref.scorer_mlp_ref(h, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-2, atol=2e-2)
