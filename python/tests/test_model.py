"""L2 model-graph correctness: prefill/decode agreement, masking, KV layout.

The critical invariant for the serving engine: running tokens one at a time
through `decode_step` must reproduce the logits/hiddens `prefill` assigns
to the same positions — otherwise the rust engine's incremental decoding
diverges from the model.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig(max_len=64)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


def test_prefill_shapes(params):
    toks = jnp.asarray(np.full((2, 8), 5), jnp.int32)
    logits, hidden, kv = M.prefill(CFG, params, toks)
    assert logits.shape == (2, 8, CFG.vocab)
    assert hidden.shape == (2, 8, CFG.d_model)
    assert kv.shape == (CFG.n_layers, 2, 2, CFG.n_heads, CFG.max_len,
                        CFG.head_dim)


def test_prefill_kv_zero_beyond_prompt(params):
    toks = jnp.asarray(np.full((1, 8), 5), jnp.int32)
    _, _, kv = M.prefill(CFG, params, toks)
    assert np.all(np.asarray(kv)[:, :, :, :, 8:, :] == 0.0)


def test_decode_matches_prefill(params):
    """Token-by-token decode must equal prefill at every position."""
    rng = np.random.default_rng(0)
    seq = rng.integers(4, CFG.vocab, size=12).astype(np.int32)
    seq[0] = M.ModelConfig.BOS
    toks = jnp.asarray(seq[None, :])
    logits_all, hidden_all, _ = M.prefill(CFG, params, toks)

    # Prefill the first 4 tokens, then decode the rest one at a time.
    p = 4
    _, _, kv = M.prefill(CFG, params, toks[:, :p])
    for i in range(p, len(seq)):
        logits, hidden, kv = M.decode_step(
            CFG, params, kv,
            jnp.asarray([seq[i]], jnp.int32),
            jnp.asarray([i], jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits[0]), np.asarray(logits_all[0, i]),
            rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(
            np.asarray(hidden[0]), np.asarray(hidden_all[0, i]),
            rtol=2e-4, atol=2e-4)


def test_prefill_pad_invariance(params):
    """Right-padding a prompt must not change logits at real positions."""
    rng = np.random.default_rng(1)
    seq = rng.integers(4, CFG.vocab, size=6).astype(np.int32)
    short = jnp.asarray(seq[None, :])
    padded = jnp.asarray(
        np.concatenate([seq, np.zeros(4, np.int32)])[None, :])
    l1, h1, _ = M.prefill(CFG, params, short)
    l2, h2, _ = M.prefill(CFG, params, padded)
    np.testing.assert_allclose(np.asarray(l1[0]), np.asarray(l2[0, :6]),
                               rtol=2e-4, atol=2e-4)


def test_batch_consistency(params):
    """Each batch lane must be independent (no cross-sequence leakage)."""
    rng = np.random.default_rng(2)
    a = rng.integers(4, CFG.vocab, size=8).astype(np.int32)
    b = rng.integers(4, CFG.vocab, size=8).astype(np.int32)
    la, _, _ = M.prefill(CFG, params, jnp.asarray(a[None, :]))
    both = jnp.asarray(np.stack([a, b]))
    lboth, _, _ = M.prefill(CFG, params, both)
    np.testing.assert_allclose(np.asarray(la[0]), np.asarray(lboth[0]),
                               rtol=2e-4, atol=2e-4)


def test_decode_batch_consistency(params):
    """Batched decode must equal per-sequence decode."""
    rng = np.random.default_rng(3)
    seqs = rng.integers(4, CFG.vocab, size=(2, 6)).astype(np.int32)
    _, _, kv2 = M.prefill(CFG, params, jnp.asarray(seqs))
    tok = jnp.asarray([7, 9], jnp.int32)
    pos = jnp.asarray([6, 6], jnp.int32)
    lb, hb, _ = M.decode_step(CFG, params, kv2, tok, pos)
    for i in range(2):
        _, _, kv1 = M.prefill(CFG, params, jnp.asarray(seqs[i:i + 1]))
        l1, h1, _ = M.decode_step(CFG, params, kv1, tok[i:i + 1], pos[i:i + 1])
        np.testing.assert_allclose(np.asarray(lb[i]), np.asarray(l1[0]),
                                   rtol=2e-4, atol=2e-4)


def test_decode_writes_kv_at_pos(params):
    toks = jnp.asarray(np.full((1, 4), 5), jnp.int32)
    _, _, kv = M.prefill(CFG, params, toks)
    _, _, kv2 = M.decode_step(CFG, params, kv,
                              jnp.asarray([6], jnp.int32),
                              jnp.asarray([4], jnp.int32))
    kv2 = np.asarray(kv2)
    assert np.any(kv2[:, :, :, :, 4, :] != 0.0)
    assert np.all(kv2[:, :, :, :, 5:, :] == 0.0)


def test_init_params_deterministic():
    p1 = M.init_params(CFG, seed=42)
    p2 = M.init_params(CFG, seed=42)
    for a, b in zip(p1, p2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    p3 = M.init_params(CFG, seed=43)
    assert not np.array_equal(np.asarray(p1.embed), np.asarray(p3.embed))


def test_scorer_graph_tuple_output():
    rng = np.random.default_rng(4)
    h = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((64, 512)) * 0.1, jnp.float32)
    b1 = jnp.zeros((512,), jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((512, 1)) * 0.1, jnp.float32)
    b2 = jnp.zeros((1,), jnp.float32)
    out = M.scorer_graph(h, w1, b1, w2, b2)
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (8,)
