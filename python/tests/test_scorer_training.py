"""Step-scorer training pipeline (paper §4.1 + Appendix A).

Checks the synthetic-trace dataset construction (balance, label
propagation, imbalance ratio), the Appendix-A training loop (weighted BCE
converges to a discriminative scorer), and the export format consumed by
the rust engine.
"""

import json

import numpy as np
import pytest

from compile import scorer as S

GP = S.GenParams(d=16)  # small dim for fast tests


def test_signal_direction_unit_norm():
    u = S.signal_direction(64)
    assert u.shape == (64,)
    np.testing.assert_allclose(np.linalg.norm(u), 1.0, rtol=1e-6)
    np.testing.assert_array_equal(u, S.signal_direction(64))  # deterministic


def test_trace_hiddens_shapes_and_growth():
    rng = np.random.default_rng(0)
    u = S.signal_direction(GP.d)
    w_q = np.zeros(GP.d, np.float32)
    h = S.sample_trace_hiddens(GP, 1, rng, u, w_q, n_steps=50)
    assert h.shape == (50, GP.d)
    # The projection onto u must grow (in expectation) with step index for
    # correct traces: compare mean projection of early vs late thirds over
    # many traces.
    early, late = [], []
    for _ in range(200):
        h = S.sample_trace_hiddens(GP, 1, rng, u, w_q, n_steps=45)
        proj = h @ u
        early.append(proj[:15].mean())
        late.append(proj[-15:].mean())
    assert np.mean(late) > np.mean(early) + 0.2


def test_trace_hiddens_label_separation():
    rng = np.random.default_rng(1)
    u = S.signal_direction(GP.d)
    w_q = np.zeros(GP.d, np.float32)
    pos = np.mean([S.sample_trace_hiddens(GP, 1, rng, u, w_q, n_steps=40) @ u
                   for _ in range(100)])
    neg = np.mean([S.sample_trace_hiddens(GP, 0, rng, u, w_q, n_steps=40) @ u
                   for _ in range(100)])
    assert pos > 0.3 and neg < -0.3


def test_dataset_balanced_at_trace_level():
    X, y, tid = S.build_dataset(GP, n_traces_per_class=40, seed=0)
    assert X.shape[1] == GP.d
    assert len(X) == len(y) == len(tid)
    # Trace-level balance.
    labels_per_trace = {}
    for t, lab in zip(tid, y):
        labels_per_trace.setdefault(int(t), lab)
    vals = np.array(list(labels_per_trace.values()))
    assert (vals == 1).sum() == 40 and (vals == 0).sum() == 40
    # Step-level imbalance: incorrect traces are longer => more neg steps.
    assert (y == 0).sum() > (y == 1).sum()


def test_dataset_label_propagation_constant_within_trace():
    _, y, tid = S.build_dataset(GP, n_traces_per_class=10, seed=1)
    for t in np.unique(tid):
        assert len(np.unique(y[tid == t])) == 1


def test_auc_helper():
    y = np.array([1, 1, 0, 0], np.float32)
    assert S._auc(y, np.array([0.9, 0.8, 0.2, 0.1])) == 1.0
    assert S._auc(y, np.array([0.1, 0.2, 0.8, 0.9])) == 0.0
    assert abs(S._auc(y, np.array([0.5, 0.1, 0.5, 0.1])) - 0.5) < 1e-9


@pytest.mark.slow
def test_training_learns_discriminative_scorer():
    gp = S.GenParams(d=16)
    weights, metrics = S.train_scorer(
        gp, n_traces_per_class=150, max_epochs=8, seed=0)
    assert metrics["val_auc"] > 0.75
    assert metrics["alpha"] > 1.0  # more negative steps than positive
    assert weights["w1"].shape == (16, 512)
    assert weights["w2"].shape == (512, 1)


def test_export_roundtrip(tmp_path):
    gp = S.GenParams(d=8)
    w = S.init_mlp(8, hidden=32)
    path = tmp_path / "scorer.json"
    S.export_scorer(str(path), gp, w, {"val_auc": 0.9})
    blob = json.loads(path.read_text())
    assert blob["d"] == 8
    assert blob["hidden"] == 32
    assert len(blob["w1"]) == 8 * 32
    assert len(blob["signal_dir"]) == 8
    assert blob["gen"]["s0"] == gp.s0
    np.testing.assert_allclose(
        np.array(blob["w1"]).reshape(8, 32), w["w1"], rtol=1e-6)
