"""Pallas prefill flash-attention kernel vs oracle (hypothesis sweeps)."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.prefill_attention import prefill_attention

SETTINGS = dict(max_examples=20, deadline=None)


def rand(rng, *shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


@hypothesis.given(
    b=st.integers(1, 3),
    h=st.integers(1, 3),
    p_tiles=st.integers(1, 3),
    dh=st.sampled_from([16, 32]),
    bq=st.sampled_from([32, 64]),
    bk=st.sampled_from([32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
@hypothesis.settings(**SETTINGS)
def test_prefill_matches_ref(b, h, p_tiles, dh, bq, bk, seed):
    p = p_tiles * max(bq, bk)
    rng = np.random.default_rng(seed)
    q = rand(rng, b, h, p, dh)
    k = rand(rng, b, h, p, dh)
    v = rand(rng, b, h, p, dh)
    lens = jnp.asarray(rng.integers(1, p + 1, size=b), jnp.int32)
    out = prefill_attention(q, k, v, lens, block_q=bq, block_k=bk)
    exp = ref.prefill_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


def test_prefill_first_position_is_value():
    """Position 0 can only attend itself: output == v[:, :, 0]."""
    rng = np.random.default_rng(0)
    b, h, p, dh = 2, 2, 64, 16
    q = rand(rng, b, h, p, dh)
    k = rand(rng, b, h, p, dh)
    v = rand(rng, b, h, p, dh)
    lens = jnp.asarray([p, p], jnp.int32)
    out = np.asarray(prefill_attention(q, k, v, lens))
    np.testing.assert_allclose(out[:, :, 0], np.asarray(v)[:, :, 0],
                               rtol=1e-5, atol=1e-6)


def test_prefill_causality():
    """Changing future K/V must not affect earlier outputs."""
    rng = np.random.default_rng(1)
    b, h, p, dh = 1, 2, 128, 16
    q = rand(rng, b, h, p, dh)
    k = rand(rng, b, h, p, dh)
    v = rand(rng, b, h, p, dh)
    lens = jnp.asarray([p], jnp.int32)
    out1 = np.asarray(prefill_attention(q, k, v, lens))
    k2 = k.at[:, :, 64:, :].add(100.0)
    v2 = v.at[:, :, 64:, :].add(-50.0)
    out2 = np.asarray(prefill_attention(q, k2, v2, lens))
    np.testing.assert_array_equal(out1[:, :, :64], out2[:, :, :64])
    assert not np.allclose(out1[:, :, 64:], out2[:, :, 64:])


def test_prefill_padding_does_not_leak_into_valid_rows():
    """Garbage in padded K/V and q rows must not change valid outputs;
    padded rows themselves stay finite (they attend the valid prefix)."""
    rng = np.random.default_rng(2)
    b, h, p, dh = 2, 1, 64, 16
    q = rand(rng, b, h, p, dh)
    k = rand(rng, b, h, p, dh)
    v = rand(rng, b, h, p, dh)
    lens = jnp.asarray([10, 64], jnp.int32)
    out1 = np.asarray(prefill_attention(q, k, v, lens))
    # Poison everything beyond the valid length of sequence 0.
    k2 = k.at[0, :, 10:, :].set(1e5)
    v2 = v.at[0, :, 10:, :].set(-1e5)
    out2 = np.asarray(prefill_attention(q, k2, v2, lens))
    np.testing.assert_array_equal(out1[0, :, :10], out2[0, :, :10])
    np.testing.assert_array_equal(out1[1], out2[1])
    assert np.isfinite(out1).all()


def test_prefill_agrees_with_decode_kernel_last_row():
    """The prefill kernel's last valid row equals decode attention over
    the same prefix — the two L1 kernels must be mutually consistent."""
    from compile.kernels.attention import decode_attention

    rng = np.random.default_rng(3)
    b, h, p, dh = 2, 2, 64, 32
    q = rand(rng, b, h, p, dh)
    k = rand(rng, b, h, p, dh)
    v = rand(rng, b, h, p, dh)
    lens = jnp.asarray([40, 64], jnp.int32)
    pre = np.asarray(prefill_attention(q, k, v, lens))
    for bi, ln in enumerate([40, 64]):
        q_last = q[bi:bi + 1, :, ln - 1, :]
        dec = np.asarray(decode_attention(
            q_last, k[bi:bi + 1], v[bi:bi + 1],
            jnp.asarray([ln], jnp.int32), block_k=32))
        np.testing.assert_allclose(pre[bi, :, ln - 1], dec[0],
                                   rtol=1e-5, atol=1e-5)


def test_prefill_rejects_misaligned_tiles():
    rng = np.random.default_rng(4)
    q = rand(rng, 1, 1, 100, 16)
    with pytest.raises(ValueError, match="tiles"):
        prefill_attention(q, q, q, jnp.asarray([50], jnp.int32),
                          block_q=64, block_k=64)
