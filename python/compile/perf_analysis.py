"""L1/L2 §Perf analysis: XLA cost analysis of the lowered serving graphs
plus analytic VMEM/MXU estimates for the Pallas kernels (interpret=True
gives CPU-numpy wallclock only, so TPU behaviour is *estimated* from the
BlockSpec structure — DESIGN.md §8).

Run: cd python && python -m compile.perf_analysis
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model as M
from .aot import kv_spec, param_specs, _spec


def cost(fn, *specs):
    lowered = jax.jit(fn).lower(*specs)
    compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return ca


def decode_cost(cfg, batch):
    specs = [s for _, s in param_specs(cfg)] + [
        kv_spec(cfg, batch),
        _spec((batch,), jnp.int32),
        _spec((batch,), jnp.int32),
    ]

    def fn(*args):
        p = M.Params(*args[:14])
        return M.decode_step(cfg, p, args[14], args[15], args[16])

    return cost(fn, *specs)


def main():
    cfg = M.ModelConfig(max_len=256)
    print("== L2 decode-step cost analysis (XLA) ==")
    for b in (1, 8):
        ca = decode_cost(cfg, b)
        flops = ca.get("flops", float("nan"))
        bytes_ = ca.get("bytes accessed", float("nan"))
        print(f"  batch {b}: {flops:.3e} flops, {bytes_:.3e} bytes accessed, "
              f"arithmetic intensity {flops / max(bytes_, 1):.2f} flop/byte")
    # Analytic model FLOPs: 2 * params * batch per token (sanity bound).
    n_params = 3.4e6
    print(f"  analytic 2*N*b bound (b=8): {2 * n_params * 8:.3e} flops")

    print("\n== L1 Pallas decode-attention: TPU estimates (per (b,h) program) ==")
    dh, bk, m = cfg.head_dim, 128, cfg.max_len
    tile_bytes = 2 * bk * dh * 4
    print(f"  KV tile (block_k={bk}): {tile_bytes / 1024:.0f} KiB; "
          f"double-buffered working set {2 * tile_bytes / 1024:.0f} KiB "
          f"(<< 16 MiB VMEM)")
    flops_per_tile = 2 * 2 * bk * dh
    print(f"  {flops_per_tile / tile_bytes:.2f} flop/byte -> HBM-bandwidth bound "
          "(decode attention roofline; MXU M-dim occupancy 1/128 per program,")
    print("  recover by stacking heads/sequences into the M dimension — noted as")
    print("  the production packing strategy in EXPERIMENTS.md §Perf)")

    print("\n== L1 Pallas scorer MLP: TPU estimates ==")
    d, hm, bb = 64, 512, 64
    w_bytes = (d * hm + hm) * 4
    print(f"  weights resident in VMEM: {w_bytes / 1024:.0f} KiB; "
          f"batch tile {bb}x{d} = {bb * d * 4 / 1024:.0f} KiB")
    g1 = 2 * bb * d * hm
    print(f"  GEMM1 {bb}x{d}x{hm}: {g1:.2e} flops, MXU tiles "
          f"{(bb + 127) // 128}x{(d + 127) // 128}x{(hm + 127) // 128} -> "
          "M=64 half-occupied; K=64 half; ~25% MXU utilization at b=64")


if __name__ == "__main__":
    main()
