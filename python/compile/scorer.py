"""Step-scorer definition + training (paper §4.1, Appendix A).

The scorer is the paper's 2-layer MLP — Input -> 512 (ReLU) -> 1, sigmoid —
trained with the class-imbalance-weighted BCE of §4.1 (alpha = K-/K+)
using Adam (lr 1e-4, weight decay 1e-5, batch 128, <=20 epochs, early
stopping patience 5), exactly the Appendix-A recipe.

Training data substitution (DESIGN.md §3): the paper samples 64 traces per
HMMT-2012-23 problem from the target LLM and keeps 5 000 correct + 5 000
incorrect verified traces. Without those models we train on hidden states
from the *synthetic trace generator* — the same generative process the
rust simulator (rust/src/sim/tracegen.rs) uses, with parameters exported
alongside the weights so the two sides stay in sync:

  per question q:   nuisance direction w_q ~ N(0, I) * c_q / sqrt(d)
  per trace t:      latent quality  g_t = (2y-1) + nu_t,  nu_t ~ N(0, sigma_t)
  per step n:       progress        rho_n = n / (n + n0)
                    h_n = s0 * rho_n * g_t * u  +  w_q  +  sigma_h * eps_n

`u` is a fixed unit signal direction. Early steps have low SNR (rho small)
and the per-trace latent noise nu_t caps attainable ranking accuracy —
which is precisely the structure the paper measures (Fig. 2a, Fig. 5:
discriminability grows with prefix length but saturates below 100%).

Trace-level pseudo-labels are propagated to every step (the paper's label
construction), so the training set carries the same label noise.

Outputs (via aot.py): artifacts/scorer_<name>.json with weights, the
signal direction, and the generator parameters.
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np


@dataclasses.dataclass(frozen=True)
class GenParams:
    """Synthetic hidden-state generator parameters (shared with rust sim).

    Calibrated so the *trace-level* discriminability matches Fig. 2a /
    Fig. 5: sigma_t bounds the attainable RankAcc plateau (~0.88), n0
    makes the signal emerge over the first ~25% of a ~300-step trace,
    and step counts match the serving workload (~1e2 tokens/step over
    20-45k-token traces)."""

    d: int = 64            # hidden dimension
    s0: float = 2.2        # asymptotic signal strength
    n0: float = 60.0       # progress half-saturation step count
    sigma_h: float = 1.0   # per-step isotropic noise
    sigma_t: float = 1.15  # per-trace latent-quality noise (AUC ceiling)
    c_q: float = 0.6       # per-question nuisance scale
    sigma_a: float = 1.3   # transient early-trace offset along u (decays)
    tau: float = 45.0      # decay constant (steps) of the transient
    steps_correct_mean: float = 230.0   # mean #steps, correct traces
    steps_incorrect_mean: float = 280.0 # incorrect traces run longer (Fig 2b)
    steps_sigma: float = 0.30           # lognormal sigma of step counts


def signal_direction(d: int, seed: int = 7) -> np.ndarray:
    """The fixed unit vector the correctness signal lives along."""
    rng = np.random.default_rng(seed)
    u = rng.standard_normal(d)
    return (u / np.linalg.norm(u)).astype(np.float32)


def sample_trace_hiddens(gp: GenParams, y: int, rng: np.random.Generator,
                         u: np.ndarray, w_q: np.ndarray,
                         n_steps: int | None = None) -> np.ndarray:
    """Hidden states at every step boundary of one trace. [N, d] f32."""
    if n_steps is None:
        mean = gp.steps_correct_mean if y == 1 else gp.steps_incorrect_mean
        n_steps = max(4, int(rng.lognormal(np.log(mean), gp.steps_sigma)))
    g = (2 * y - 1) + rng.normal(0.0, gp.sigma_t)
    a = rng.normal(0.0, gp.sigma_a)  # early-exploration transient
    n = np.arange(1, n_steps + 1, dtype=np.float32)
    rho = n / (n + gp.n0)
    sig = gp.s0 * rho * g + a * np.exp(-n / gp.tau)
    h = sig[:, None] * u[None, :]
    h += w_q[None, :]
    h += rng.standard_normal((n_steps, gp.d)).astype(np.float32) * gp.sigma_h
    return h.astype(np.float32)


def build_dataset(gp: GenParams, n_traces_per_class: int = 5000,
                  n_questions: int = 120, seed: int = 0):
    """Balanced trace-level dataset, all steps kept (paper §4.1).

    Returns (X [S, d], y_step [S], trace_id [S]).
    """
    rng = np.random.default_rng(seed)
    u = signal_direction(gp.d)
    w_qs = rng.standard_normal((n_questions, gp.d)).astype(np.float32)
    w_qs *= gp.c_q / np.sqrt(gp.d)
    xs, ys, tids = [], [], []
    tid = 0
    for y in (1, 0):
        for _ in range(n_traces_per_class):
            w_q = w_qs[rng.integers(0, n_questions)]
            h = sample_trace_hiddens(gp, y, rng, u, w_q)
            xs.append(h)
            ys.append(np.full(len(h), y, np.float32))
            tids.append(np.full(len(h), tid, np.int64))
            tid += 1
    return np.concatenate(xs), np.concatenate(ys), np.concatenate(tids)


def init_mlp(d: int, hidden: int = 512, seed: int = 1):
    rng = np.random.default_rng(seed)
    return {
        "w1": (rng.standard_normal((d, hidden)) * (2.0 / d) ** 0.5).astype(np.float32),
        "b1": np.zeros(hidden, np.float32),
        "w2": (rng.standard_normal((hidden, 1)) * (2.0 / hidden) ** 0.5).astype(np.float32),
        "b2": np.zeros(1, np.float32),
    }


def train_scorer(gp: GenParams, *, n_traces_per_class: int = 5000,
                 batch_size: int = 128, max_epochs: int = 20,
                 patience: int = 5, lr: float = 1e-4, weight_decay: float = 1e-5,
                 seed: int = 0, verbose: bool = False):
    """Appendix-A training loop (Adam + weighted BCEWithLogits).

    Returns (weights dict, metrics dict).
    """
    import jax
    import jax.numpy as jnp

    X, y, tid = build_dataset(gp, n_traces_per_class, seed=seed)
    # Trace-level split so validation traces are unseen.
    rng = np.random.default_rng(seed + 1)
    n_tr = int(tid.max()) + 1
    val_traces = set(rng.choice(n_tr, size=n_tr // 10, replace=False).tolist())
    val_mask = np.isin(tid, list(val_traces))
    Xtr, ytr = X[~val_mask], y[~val_mask]
    Xva, yva = X[val_mask], y[val_mask]

    # alpha = K- / K+ (incorrect traces are longer -> more negative steps).
    k_pos, k_neg = float((ytr == 1).sum()), float((ytr == 0).sum())
    alpha = k_neg / k_pos

    params = init_mlp(gp.d)
    params = {k: jnp.asarray(v) for k, v in params.items()}
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(v) for k, v in params.items()}

    def forward_logit(p, x):
        z = jnp.maximum(x @ p["w1"] + p["b1"], 0.0)
        return (z @ p["w2"] + p["b2"])[:, 0]

    def loss_fn(p, x, t):
        logit = forward_logit(p, x)
        # Weighted BCEWithLogits: alpha on the positive term (paper §4.1).
        pos = alpha * t * jax.nn.softplus(-logit)
        neg = (1.0 - t) * jax.nn.softplus(logit)
        return jnp.mean(pos + neg)

    b1m, b2m, eps = 0.9, 0.999, 1e-8

    @jax.jit
    def step(p, m, v, t_step, x, tgt):
        g = jax.grad(loss_fn)(p, x, tgt)
        new_p, new_m, new_v = {}, {}, {}
        for k in p:
            gk = g[k] + weight_decay * p[k]
            new_m[k] = b1m * m[k] + (1 - b1m) * gk
            new_v[k] = b2m * v[k] + (1 - b2m) * gk * gk
            mhat = new_m[k] / (1 - b1m ** t_step)
            vhat = new_v[k] / (1 - b2m ** t_step)
            new_p[k] = p[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
        return new_p, new_m, new_v

    @jax.jit
    def val_loss(p):
        return loss_fn(p, jnp.asarray(Xva), jnp.asarray(yva))

    n = len(Xtr)
    order = np.arange(n)
    best, best_params, bad_epochs, t_step = np.inf, params, 0, 0
    history = []
    for epoch in range(max_epochs):
        rng.shuffle(order)
        for i in range(0, n - batch_size + 1, batch_size):
            idx = order[i:i + batch_size]
            t_step += 1
            params, m, v = step(params, m, v, t_step,
                                jnp.asarray(Xtr[idx]), jnp.asarray(ytr[idx]))
        vl = float(val_loss(params))
        history.append(vl)
        if verbose:
            print(f"epoch {epoch}: val_loss={vl:.4f}")
        if vl < best - 1e-5:
            best, best_params, bad_epochs = vl, params, 0
        else:
            bad_epochs += 1
            if bad_epochs >= patience:
                break

    weights = {k: np.asarray(val) for k, val in best_params.items()}
    # Validation AUC (step level).
    logit = np.asarray(forward_logit(best_params, jnp.asarray(Xva)))
    auc = _auc(yva, logit)
    metrics = {"val_loss": best, "val_auc": auc, "alpha": alpha,
               "epochs": len(history)}
    return weights, metrics


def _auc(y, s) -> float:
    """Mann-Whitney AUC with tie-averaged ranks."""
    s = np.asarray(s, np.float64)
    order = np.argsort(s)
    ranks = np.empty(len(s), np.float64)
    i = 0
    while i < len(s):
        j = i
        while j + 1 < len(s) and s[order[j + 1]] == s[order[i]]:
            j += 1
        ranks[order[i:j + 1]] = (i + j) / 2.0 + 1.0
        i = j + 1
    n_pos = float((y == 1).sum())
    n_neg = float((y == 0).sum())
    return float((ranks[y == 1].sum() - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg))


def export_scorer(path: str, gp: GenParams, weights: dict, metrics: dict):
    """JSON bundle consumed by rust (scorer weights + generator params)."""
    u = signal_direction(gp.d)
    blob = {
        "d": gp.d,
        "hidden": int(weights["w1"].shape[1]),
        "w1": weights["w1"].flatten().tolist(),
        "b1": weights["b1"].tolist(),
        "w2": weights["w2"].flatten().tolist(),
        "b2": weights["b2"].tolist(),
        "signal_dir": u.tolist(),
        "gen": dataclasses.asdict(gp),
        "metrics": {k: float(v) for k, v in metrics.items()},
    }
    with open(path, "w") as f:
        json.dump(blob, f)
