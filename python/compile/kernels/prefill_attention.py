"""L1 Pallas kernel: causal (flash-style) prefill attention.

The prompt-processing counterpart of kernels/attention.py: each program
owns one (batch, head, q-tile) triple and streams K/V tiles through VMEM
with an online softmax, skipping fully-masked KV tiles (causality) — the
standard flash-attention schedule re-expressed with BlockSpec index maps
for the TPU memory hierarchy (DESIGN.md §Hardware-Adaptation).

Padding: positions >= lens[b] are masked out of the attention (the rust
engine right-pads batched prompts of different lengths).

interpret=True as everywhere (CPU PJRT cannot run Mosaic custom-calls).
Oracle: ref-style masked softmax in tests (python/tests/test_kernels.py's
prefill section) and the jnp prefill in model.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64


def _prefill_attn_kernel(lens_ref, q_ref, k_ref, v_ref, o_ref, *,
                         block_q: int, block_k: int, seq_len: int):
    """One (b, h, iq) program: causal online-softmax over KV tiles.

    Refs: lens [1]; q [1,1,block_q,Dh]; k,v [1,1,P,Dh]; o like q.
    """
    dh = q_ref.shape[-1]
    iq = pl.program_id(2)
    q_start = iq * block_q
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    q = q_ref[0, 0, :, :].astype(jnp.float32) * scale  # [bq, Dh]
    valid_len = lens_ref[0]
    q_idx = q_start + jax.lax.iota(jnp.int32, block_q)

    # Causality: only KV tiles with start <= last query index matter.
    num_kv_tiles = (q_start + block_q + block_k - 1) // block_k

    def body(j, carry):
        m_prev, l_prev, acc = carry
        k_start = j * block_k
        k_tile = k_ref[0, 0, pl.dslice(k_start, block_k), :].astype(jnp.float32)
        v_tile = v_ref[0, 0, pl.dslice(k_start, block_k), :].astype(jnp.float32)
        s = q @ k_tile.T  # [bq, bk]
        k_idx = k_start + jax.lax.iota(jnp.int32, block_k)
        mask = (k_idx[None, :] <= q_idx[:, None]) & (k_idx < valid_len)[None, :]
        s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_new), 0.0)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[:, None] + p @ v_tile
        return m_new, l_new, acc

    m0 = jnp.full((block_q,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, dh), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, num_kv_tiles, body, (m0, l0, acc0))
    # Padded / out-of-range query rows normalize by l=0 -> emit zeros.
    safe_l = jnp.where(l > 0.0, l, 1.0)
    out = jnp.where((l > 0.0)[:, None], acc / safe_l[:, None], 0.0)
    o_ref[0, 0, :, :] = out.astype(o_ref.dtype)
    del seq_len


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def prefill_attention(q, k, v, lens, *, block_q: int | None = None,
                      block_k: int | None = None):
    """Causal Pallas prefill attention.

    Args:
      q, k, v: [B, H, P, Dh] (P a multiple of the tile sizes).
      lens:    [B] int32 valid prompt lengths (padding masked out).
    Returns:
      [B, H, P, Dh]; rows at positions >= lens are zeros.
    """
    B, H, P, Dh = q.shape
    bq = block_q or min(DEFAULT_BLOCK_Q, P)
    bk = block_k or min(DEFAULT_BLOCK_K, P)
    if P % bq != 0 or P % bk != 0:
        raise ValueError(f"prompt length {P} not a multiple of tiles ({bq},{bk})")
    kernel = functools.partial(
        _prefill_attn_kernel, block_q=bq, block_k=bk, seq_len=P
    )
    return pl.pallas_call(
        kernel,
        grid=(B, H, P // bq),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, i: (b,)),
            pl.BlockSpec((1, 1, bq, Dh), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, P, Dh), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, P, Dh), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dh), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, P, Dh), q.dtype),
        interpret=True,
    )(lens, q, k, v)
