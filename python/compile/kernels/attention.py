"""L1 Pallas kernel: single-token decode attention over a cached KV prefix.

This is the serving hot-spot: at every engine iteration each live trace
attends its new query token against its (growing) KV cache. The paper's
testbed ran this on a GH200 via vLLM's CUDA kernels (one threadblock per
(sequence, head), KV streamed HBM -> shared memory). The TPU re-think
(DESIGN.md §Hardware-Adaptation):

  * grid = (batch, heads): each Pallas program owns one (b, h) pair;
  * the KV cache is tiled HBM -> VMEM with `BlockSpec` in (block_k, Dh)
    chunks — VMEM plays the role CUDA shared memory played, but the
    schedule is expressed declaratively via the index map instead of
    imperatively via threadblock loops;
  * q.K^T and P.V are (1, Dh) x (Dh, block_k) / (1, block_k) x (block_k,
    Dh) contractions that map onto the MXU, accumulated in f32 with an
    online (flash-style) softmax so only one KV tile is resident at a
    time.

MUST be lowered with interpret=True: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute. Correctness is pinned to
ref.decode_attention_ref by python/tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_K = 128


def _decode_attn_kernel(lens_ref, q_ref, k_ref, v_ref, o_ref, *, block_k: int,
                        num_kv_blocks: int):
    """One (batch, head) program: online-softmax attention over KV tiles.

    Refs (as blocked by the BlockSpecs below):
      lens_ref: [1]              valid cache length for this sequence.
      q_ref:    [1, 1, Dh]       the query row for this (b, h).
      k_ref:    [1, 1, M, Dh]    full K for this (b, h) — sliced per tile.
      v_ref:    [1, 1, M, Dh]    full V for this (b, h).
      o_ref:    [1, 1, Dh]       output row.
    """
    dh = q_ref.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    q = q_ref[0, 0, :].astype(jnp.float32)[None, :] * scale  # [1, Dh]
    seq_len = lens_ref[0]

    def body(i, carry):
        m_prev, l_prev, acc = carry
        start = i * block_k
        k_tile = k_ref[0, 0, pl.dslice(start, block_k), :].astype(jnp.float32)
        v_tile = v_ref[0, 0, pl.dslice(start, block_k), :].astype(jnp.float32)
        # (1, Dh) x (Dh, block_k) -> MXU contraction.
        s = q @ k_tile.T  # [1, block_k]
        idx = start + jax.lax.iota(jnp.int32, block_k)
        s = jnp.where((idx < seq_len)[None, :], s, -jnp.inf)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        # exp(-inf - -inf) guard: m_new is finite once any position is valid;
        # before that both p and correction are zero.
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_new), 0.0)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[:, None] + p @ v_tile  # [1, Dh]
        return m_new, l_new, acc

    m0 = jnp.full((1,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((1,), jnp.float32)
    acc0 = jnp.zeros((1, dh), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, num_kv_blocks, body, (m0, l0, acc0))
    o_ref[0, 0, :] = (acc / l[:, None])[0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q, k, v, lens, *, block_k: int | None = None):
    """Pallas decode attention. Shapes as in ref.decode_attention_ref.

    Args:
      q:    [B, H, Dh]
      k, v: [B, H, M, Dh]  (M must be a multiple of block_k)
      lens: [B] int32
      block_k: KV tile length; defaults to min(DEFAULT_BLOCK_K, M).
    Returns:
      [B, H, Dh]
    """
    B, H, M, Dh = k.shape
    if block_k is None:
        block_k = min(DEFAULT_BLOCK_K, M)
    if M % block_k != 0:
        raise ValueError(f"cache length {M} not a multiple of block_k={block_k}")
    num_kv_blocks = M // block_k

    kernel = functools.partial(
        _decode_attn_kernel, block_k=block_k, num_kv_blocks=num_kv_blocks
    )
    return pl.pallas_call(
        kernel,
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h: (b,)),            # lens
            pl.BlockSpec((1, 1, Dh), lambda b, h: (b, h, 0)),  # q
            pl.BlockSpec((1, 1, M, Dh), lambda b, h: (b, h, 0, 0)),  # k
            pl.BlockSpec((1, 1, M, Dh), lambda b, h: (b, h, 0, 0)),  # v
        ],
        out_specs=pl.BlockSpec((1, 1, Dh), lambda b, h: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Dh), q.dtype),
        interpret=True,
    )(lens, q, k, v)
