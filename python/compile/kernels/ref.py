"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the CORE correctness signal for the compile path: every Pallas
kernel in this package must match its oracle here to float tolerance under
pytest (python/tests/test_kernels.py), including hypothesis sweeps over
shapes and dtypes.
"""

from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q, k, v, lens):
    """Single-token decode attention over a cached KV prefix.

    Args:
      q:    [B, H, Dh]     query for the token being decoded.
      k:    [B, H, M, Dh]  cached keys (padded to max length M).
      v:    [B, H, M, Dh]  cached values.
      lens: [B] int32      number of valid cache positions per sequence
                           (the new token's K/V must already be written at
                           position lens-1).

    Returns:
      [B, H, Dh] attention output, computed in f32 and cast back to q.dtype.
    """
    _, _, M, Dh = k.shape
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(Dh))
    logits = jnp.einsum("bhd,bhmd->bhm", qf, kf) * scale  # [B, H, M]
    mask = jnp.arange(M)[None, None, :] < lens[:, None, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    out = jnp.einsum("bhm,bhmd->bhd", probs, vf)
    return out.astype(q.dtype)


def scorer_mlp_ref(h, w1, b1, w2, b2):
    """Step-scorer MLP: sigmoid(W2 @ relu(W1 @ h + b1) + b2).

    Args:
      h:  [B, D]   step-boundary hidden states.
      w1: [D, Hm]  first layer weight.
      b1: [Hm]
      w2: [Hm, 1]  output head.
      b2: [1]

    Returns:
      [B] correctness probabilities in f32.
    """
    hf = h.astype(jnp.float32)
    z = jnp.maximum(hf @ w1.astype(jnp.float32) + b1.astype(jnp.float32), 0.0)
    logit = z @ w2.astype(jnp.float32) + b2.astype(jnp.float32)
    return 1.0 / (1.0 + jnp.exp(-logit[:, 0]))


def layernorm_ref(x, gamma, eps=1e-5):
    """Layernorm (zero-mean, unit-variance, scale only — no bias)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) / jnp.sqrt(var + eps) * gamma).astype(x.dtype)


def prefill_attention_ref(q, k, v, lens):
    """Causal masked attention over a padded prompt batch.

    q, k, v: [B, H, P, Dh]; lens: [B]. Rows at positions >= lens[b]
    produce zeros (fully masked).
    """
    B, H, P, Dh = q.shape
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(Dh))
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    causal = jnp.tril(jnp.ones((P, P), bool))
    valid = jnp.arange(P)[None, :] < lens[:, None]  # [B, P] keys
    mask = causal[None, None] & valid[:, None, None, :]
    s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(jnp.isfinite(s), jnp.exp(s - jnp.where(jnp.isfinite(m), m, 0.0)), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p / jnp.where(l > 0, l, 1.0), vf)
    out = jnp.where((l > 0), out, 0.0)
    return out.astype(q.dtype)
