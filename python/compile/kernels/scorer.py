"""L1 Pallas kernel: fused step-scorer MLP.

The paper's step scorer (§4.1) is sigmoid(W2 relu(W1 h + b1) + b2) applied
to the last-layer hidden state of every `\n\n` step-boundary token. In the
serving loop it runs once per boundary per live trace, so it sits on the
decode hot path — the paper keeps its overhead < 1e-6 of an LLM step
(App. D) by construction.

TPU mapping (DESIGN.md §Hardware-Adaptation): the whole MLP fuses into one
Pallas program — both weight matrices stay resident in VMEM (512·D·4 B ≈
0.5–5 MB, well under the ~16 MB budget), activations never round-trip to
HBM, and both layers are MXU contractions: (Bt, D)x(D, 512) then
(Bt, 512)x(512, 1). Grid tiles the batch so large scoring batches stream
through the same resident weights.

interpret=True: see kernels/attention.py. Oracle: ref.scorer_mlp_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 64


def _scorer_kernel(h_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    """One batch-tile program: fused 2-layer MLP + sigmoid.

    Refs: h [Bt, D], w1 [D, Hm], b1 [Hm], w2 [Hm, 1], b2 [1], o [Bt].
    """
    h = h_ref[...].astype(jnp.float32)
    z = h @ w1_ref[...].astype(jnp.float32) + b1_ref[...].astype(jnp.float32)
    z = jnp.maximum(z, 0.0)
    logit = z @ w2_ref[...].astype(jnp.float32) + b2_ref[...].astype(jnp.float32)
    o_ref[...] = 1.0 / (1.0 + jnp.exp(-logit[:, 0]))


@functools.partial(jax.jit, static_argnames=("block_b",))
def scorer_mlp(h, w1, b1, w2, b2, *, block_b: int = DEFAULT_BLOCK_B):
    """Fused Pallas scorer MLP. Shapes as in ref.scorer_mlp_ref.

    Args:
      h:  [B, D] hidden states (B must be a multiple of block_b, or < block_b
          in which case a single-tile launch is used).
      w1: [D, Hm], b1: [Hm], w2: [Hm, 1], b2: [1].
    Returns:
      [B] f32 probabilities.
    """
    B, D = h.shape
    Hm = w1.shape[1]
    bb = min(block_b, B)
    if B % bb != 0:
        raise ValueError(f"batch {B} not a multiple of block_b={bb}")
    return pl.pallas_call(
        _scorer_kernel,
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((bb, D), lambda i: (i, 0)),
            pl.BlockSpec((D, Hm), lambda i: (0, 0)),
            pl.BlockSpec((Hm,), lambda i: (0,)),
            pl.BlockSpec((Hm, 1), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        interpret=True,
    )(h, w1, b1, w2, b2)
