"""L2: the reasoning-LM compute graph in JAX (build-time only).

A small decoder-only transformer standing in for the paper's reasoning
LLMs (Qwen3-4B / DeepSeek-R1-8B / Phi-4 — see DESIGN.md §3 for the
substitution argument). Two graphs are AOT-lowered per batch-size
variant and executed from rust via PJRT:

  * prefill(params, tokens)            -> (logits, hidden_last, kv)
  * decode_step(params, kv, tok, pos)  -> (logits, hidden, kv')

and the step-scorer graph (scorer weights trained in scorer.py):

  * scorer(h, w1, b1, w2, b2)          -> probs

Both phases call the L1 Pallas kernels so they lower into the same HLO
the rust runtime loads: kernels.prefill_attention (flash-style causal)
for prompt processing, kernels.attention for the per-token KV-cache
attention, and kernels.scorer for the step-scorer MLP.

Python never runs at serving time; rust owns sampling, step segmentation,
scoring policy, KV accounting and scheduling.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.attention import decode_attention
from .kernels.prefill_attention import prefill_attention
from .kernels.scorer import scorer_mlp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Tiny reasoning-LM configuration (the e2e serving model)."""

    vocab: int = 512
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 1024
    max_len: int = 512

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    # Token conventions shared with the rust tokenizer (rust/src/model):
    # 0 = pad, 1 = BOS, 2 = EOS ("</think>"-equivalent), 3 = step boundary
    # ("\n\n"-equivalent). Answer digits live at 4..14.
    PAD: int = 0
    BOS: int = 1
    EOS: int = 2
    STEP: int = 3


class Params(NamedTuple):
    """Flattened in this exact field order when lowering — the rust side
    feeds positional PJRT arguments in the same order (manifest.json)."""

    embed: jax.Array      # [V, D]
    pos_embed: jax.Array  # [M, D]
    wq: jax.Array         # [L, D, D]
    wk: jax.Array         # [L, D, D]
    wv: jax.Array         # [L, D, D]
    wo: jax.Array         # [L, D, D]
    w1: jax.Array         # [L, D, F]
    b1: jax.Array         # [L, F]
    w2: jax.Array         # [L, F, D]
    b2: jax.Array         # [L, D]
    ln1: jax.Array        # [L, D]
    ln2: jax.Array        # [L, D]
    lnf: jax.Array        # [D]
    wu: jax.Array         # [D, V] unembedding


def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    """He-style random init, deterministic in `seed`."""
    rng = np.random.default_rng(seed)
    L, D, F, V, M = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab, cfg.max_len

    def norm(*shape, scale):
        return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)

    return Params(
        embed=norm(V, D, scale=0.02),
        pos_embed=norm(M, D, scale=0.02),
        wq=norm(L, D, D, scale=D**-0.5),
        wk=norm(L, D, D, scale=D**-0.5),
        wv=norm(L, D, D, scale=D**-0.5),
        wo=norm(L, D, D, scale=D**-0.5),
        w1=norm(L, D, F, scale=D**-0.5),
        b1=jnp.zeros((L, F), jnp.float32),
        w2=norm(L, F, D, scale=F**-0.5),
        b2=jnp.zeros((L, D), jnp.float32),
        ln1=jnp.ones((L, D), jnp.float32),
        ln2=jnp.ones((L, D), jnp.float32),
        lnf=jnp.ones((D,), jnp.float32),
        wu=norm(D, V, scale=D**-0.5),
    )


def _ln(x, gamma, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma


def _split_heads(x, n_heads):  # [B, T, D] -> [B, H, T, Dh]
    B, T, D = x.shape
    return x.reshape(B, T, n_heads, D // n_heads).transpose(0, 2, 1, 3)


def prefill(cfg: ModelConfig, p: Params, tokens):
    """Process a full (padded) prompt.

    Args:
      tokens: [B, P] int32, padded with PAD after the true prompt; PAD
        positions are masked out of attention so rust may batch prompts of
        different lengths into one padded literal.

    Returns:
      logits:  [B, P, V]  next-token logits at every position.
      hidden:  [B, P, D]  final-layer hidden states (scorer input).
      kv:      [L, 2, B, H, M, Dh] cache with positions [0, P) filled.
    """
    B, P = tokens.shape
    L, H, M, Dh = cfg.n_layers, cfg.n_heads, cfg.max_len, cfg.head_dim
    x = p.embed[tokens] + p.pos_embed[:P][None, :, :]
    # Prompts are right-padded (rust engine contract), so the PAD mask
    # reduces to per-sequence valid lengths — the L1 prefill kernel's
    # masking scheme.
    lens = jnp.sum((tokens != ModelConfig.PAD).astype(jnp.int32), axis=1)

    kv_parts = []
    for l in range(L):
        h_in = _ln(x, p.ln1[l])
        q = _split_heads(h_in @ p.wq[l], H)
        k = _split_heads(h_in @ p.wk[l], H)
        v = _split_heads(h_in @ p.wv[l], H)
        # L1 Pallas flash-style causal attention over the prompt.
        o = prefill_attention(q, k, v, lens)
        o = o.transpose(0, 2, 1, 3).reshape(B, P, cfg.d_model)
        x = x + o @ p.wo[l]
        h_ff = _ln(x, p.ln2[l])
        x = x + jnp.maximum(h_ff @ p.w1[l] + p.b1[l], 0.0) @ p.w2[l] + p.b2[l]
        pad = jnp.zeros((B, H, M - P, Dh), k.dtype)
        kv_parts.append(jnp.stack([
            jnp.concatenate([k, pad], axis=2),
            jnp.concatenate([v, pad], axis=2),
        ]))

    hidden = _ln(x, p.lnf)
    logits = hidden @ p.wu
    kv = jnp.stack(kv_parts)  # [L, 2, B, H, M, Dh]
    return logits, hidden, kv


def decode_step(cfg: ModelConfig, p: Params, kv, token, pos):
    """One decode iteration for a batch of live traces.

    Args:
      kv:    [L, 2, B, H, M, Dh] cache (positions [0, pos) valid per seq).
      token: [B] int32 the tokens sampled at the previous step.
      pos:   [B] int32 the cache slot this token occupies.

    Returns:
      logits: [B, V]   next-token logits.
      hidden: [B, D]   final-layer hidden state of this token (scorer input).
      kv':    updated cache with position `pos` written in every layer.
    """
    L, H, Dh = cfg.n_layers, cfg.n_heads, cfg.head_dim
    B = token.shape[0]
    b_idx = jnp.arange(B)
    x = p.embed[token] + p.pos_embed[pos]  # [B, D]
    lens = pos + 1

    for l in range(L):
        h_in = _ln(x, p.ln1[l])
        q = (h_in @ p.wq[l]).reshape(B, H, Dh)
        k = (h_in @ p.wk[l]).reshape(B, H, Dh)
        v = (h_in @ p.wv[l]).reshape(B, H, Dh)
        kv = kv.at[l, 0, b_idx, :, pos, :].set(k)
        kv = kv.at[l, 1, b_idx, :, pos, :].set(v)
        # L1 Pallas kernel over the cached prefix (including this token).
        o = decode_attention(q, kv[l, 0], kv[l, 1], lens)  # [B, H, Dh]
        x = x + o.reshape(B, cfg.d_model) @ p.wo[l]
        h_ff = _ln(x, p.ln2[l])
        x = x + jnp.maximum(h_ff @ p.w1[l] + p.b1[l], 0.0) @ p.w2[l] + p.b2[l]

    hidden = _ln(x, p.lnf)
    logits = hidden @ p.wu
    return logits, hidden, kv


def scorer_graph(h, w1, b1, w2, b2):
    """The step-scorer graph lowered for rust (L1 Pallas fused MLP)."""
    return (scorer_mlp(h, w1, b1, w2, b2),)
