"""AOT compile path: lower every serving graph to HLO text + export weights.

Run via `make artifacts` (no-op when inputs are unchanged). Produces, in
artifacts/:

  prefill_b{B}.hlo.txt     prefill graph per batch-size variant
  decode_b{B}.hlo.txt      decode-step graph per batch-size variant
  scorer_d{D}_b{B}.hlo.txt step-scorer graph variants
  params.bin               model parameters, raw little-endian f32
  scorer_sim.json          trained sim scorer (d=64) + generator params
  scorer_e2e.json          trained e2e scorer (d=256, tiny-LM hidden size)
  manifest.json            graph/argument/parameter registry for rust

Interchange format is HLO *text*, not serialized HloModuleProto: jax >=
0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import scorer as S

PREFILL_BATCHES = (1, 4, 8)
DECODE_BATCHES = (1, 2, 4, 8)
SCORER_BATCHES = (1, 8, 64)
PROMPT_LEN = 64


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def param_specs(cfg: M.ModelConfig):
    """(name, spec) for every model parameter, in Params field order."""
    L, D, F, V, Mx = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab, cfg.max_len
    return [
        ("embed", _spec((V, D))),
        ("pos_embed", _spec((Mx, D))),
        ("wq", _spec((L, D, D))),
        ("wk", _spec((L, D, D))),
        ("wv", _spec((L, D, D))),
        ("wo", _spec((L, D, D))),
        ("w1", _spec((L, D, F))),
        ("b1", _spec((L, F))),
        ("w2", _spec((L, F, D))),
        ("b2", _spec((L, D))),
        ("ln1", _spec((L, D))),
        ("ln2", _spec((L, D))),
        ("lnf", _spec((D,))),
        ("wu", _spec((D, V))),
    ]


def kv_spec(cfg: M.ModelConfig, batch: int):
    return _spec((cfg.n_layers, 2, batch, cfg.n_heads, cfg.max_len, cfg.head_dim))


def lower_prefill(cfg: M.ModelConfig, batch: int, prompt_len: int | None = None) -> str:
    p_len = min(prompt_len or PROMPT_LEN, cfg.max_len)
    specs = [s for _, s in param_specs(cfg)] + [_spec((batch, p_len), jnp.int32)]

    def fn(*args):
        p = M.Params(*args[:-1])
        logits, hidden, kv = M.prefill(cfg, p, args[-1])
        return (logits, hidden, kv)

    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_decode(cfg: M.ModelConfig, batch: int) -> str:
    specs = [s for _, s in param_specs(cfg)] + [
        kv_spec(cfg, batch),
        _spec((batch,), jnp.int32),  # token
        _spec((batch,), jnp.int32),  # pos
    ]

    def fn(*args):
        p = M.Params(*args[:14])
        logits, hidden, kv = M.decode_step(cfg, p, args[14], args[15], args[16])
        return (logits, hidden, kv)

    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_scorer(d: int, batch: int, hidden: int = 512) -> str:
    specs = [
        _spec((batch, d)),
        _spec((d, hidden)),
        _spec((hidden,)),
        _spec((hidden, 1)),
        _spec((1,)),
    ]
    return to_hlo_text(jax.jit(M.scorer_graph).lower(*specs))


def graph_entry(file, inputs, n_outputs):
    return {
        "file": file,
        "inputs": [
            {"name": n, "shape": list(s.shape), "dtype": str(s.dtype)}
            for n, s in inputs
        ],
        "outputs": n_outputs,
    }


def export_params(cfg: M.ModelConfig, path: str, seed: int = 0):
    """Raw little-endian f32 concatenation, offsets recorded in manifest."""
    params = M.init_params(cfg, seed=seed)
    entries, bufs, offset = [], [], 0
    for (name, _), arr in zip(param_specs(cfg), params):
        a = np.asarray(arr, np.float32)
        entries.append({
            "name": name,
            "shape": list(a.shape),
            "offset": offset,       # in f32 elements
            "len": int(a.size),
        })
        bufs.append(a.flatten())
        offset += a.size
    with open(path, "wb") as f:
        f.write(np.concatenate(bufs).astype("<f4").tobytes())
    return entries


def input_fingerprint() -> str:
    """Hash of the compile-path sources; lets `make artifacts` skip cleanly."""
    here = os.path.dirname(__file__)
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        for fn in sorted(files):
            if fn.endswith(".py"):
                with open(os.path.join(root, fn), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()[:16]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--traces-per-class", type=int, default=1500,
                    help="scorer training set size per class (paper: 5000; the\n"
                    "default is smaller because traces here are ~6x longer\n"
                    "than the paper's, giving a similar step-level count)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-scorers", action="store_true",
                    help="lower graphs only (fast dev cycle)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    cfg = M.ModelConfig(max_len=256)

    graphs = {}

    def emit(name: str, text: str, inputs, n_outputs: int):
        fn = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fn), "w") as f:
            f.write(text)
        graphs[name] = graph_entry(fn, inputs, n_outputs)
        print(f"  {fn}: {len(text)} chars")

    print("lowering prefill graphs")
    for b in PREFILL_BATCHES:
        ins = param_specs(cfg) + [("tokens", _spec((b, PROMPT_LEN), jnp.int32))]
        emit(f"prefill_b{b}", lower_prefill(cfg, b), ins, 3)

    print("lowering decode graphs")
    for b in DECODE_BATCHES:
        ins = param_specs(cfg) + [
            ("kv", kv_spec(cfg, b)),
            ("token", _spec((b,), jnp.int32)),
            ("pos", _spec((b,), jnp.int32)),
        ]
        emit(f"decode_b{b}", lower_decode(cfg, b), ins, 3)

    print("lowering scorer graphs")
    for d in (64, cfg.d_model):
        for b in SCORER_BATCHES:
            ins = [
                ("h", _spec((b, d))),
                ("w1", _spec((d, 512))),
                ("b1", _spec((512,))),
                ("w2", _spec((512, 1))),
                ("b2", _spec((1,))),
            ]
            emit(f"scorer_d{d}_b{b}", lower_scorer(d, b), ins, 1)

    print("exporting model params")
    param_entries = export_params(cfg, os.path.join(args.out_dir, "params.bin"),
                                  seed=args.seed)

    scorers = {}
    if args.skip_scorers:
        # Keep previously trained scorer bundles (graph-only relower).
        for name in ("sim", "e2e"):
            if os.path.exists(os.path.join(args.out_dir, f"scorer_{name}.json")):
                scorers[name] = f"scorer_{name}.json"
    if not args.skip_scorers:
        for name, d in (("sim", 64), ("e2e", cfg.d_model)):
            print(f"training {name} scorer (d={d}) "
                  f"on {args.traces_per_class}/class synthetic traces")
            gp = S.GenParams(d=d)
            weights, metrics = S.train_scorer(
                gp, n_traces_per_class=args.traces_per_class,
                seed=args.seed, verbose=True)
            out = f"scorer_{name}.json"
            S.export_scorer(os.path.join(args.out_dir, out), gp, weights, metrics)
            scorers[name] = out
            print(f"  {out}: val_auc={metrics['val_auc']:.4f} "
                  f"alpha={metrics['alpha']:.3f} epochs={metrics['epochs']}")

    manifest = {
        "fingerprint": input_fingerprint(),
        "model_config": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff, "max_len": cfg.max_len,
            "prompt_len": PROMPT_LEN,
        },
        "graphs": graphs,
        "params_bin": "params.bin",
        "params": param_entries,
        "scorers": scorers,
        "prefill_batches": list(PREFILL_BATCHES),
        "decode_batches": list(DECODE_BATCHES),
        "scorer_batches": list(SCORER_BATCHES),
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest.json ({len(graphs)} graphs)")


if __name__ == "__main__":
    main()
